//! Gossip topology and push-sum weights (paper Section 3.1).
//!
//! LayUp communicates by *randomized gossip*: at each iteration, worker `i`
//! picks a uniformly random peer `j != i` and pushes its (already locally
//! updated) parameters, mixing them into `j`'s store with push-sum weights:
//!
//! ```text
//! w_i <- w_i / 2
//! x^{j,l} <- w_j/(w_i+w_j) * x^{j,l} + w_i/(w_i+w_j) * x^{i,l}
//! w_j <- w_j + w_i
//! ```
//!
//! Weights start at 1/M so every device contributes equally in expectation.
//! The weight exchange itself is lock-free; under contention a push may be
//! *skipped* (the weight transfer is dropped), which the paper argues — and
//! our property tests check — only delays information, never loses parameter
//! mass catastrophically. The skip counter is surfaced in metrics.
//!
//! How a push physically travels is the communication fabric's business
//! (`crate::comm`): on the instant transport the sender performs the
//! `halve`/`try_accept` handshake synchronously, on a simulated transport
//! the halved weight rides the message and the *receiver* folds it in at
//! delivery (a dropped message reclaims at the sender; a busy slot
//! re-queues) — the same conservation invariant either way.

pub mod roles;

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::util::rng::Pcg32;

/// Push-sum weight of one worker, plus a one-slot "busy" flag used to detect
/// contention (two updaters targeting the same peer simultaneously).
pub struct PushSumWeight {
    /// f32 bits; lock-free like the parameters themselves.
    w: AtomicU32,
    /// true while some updater is mid-push into this worker.
    busy: AtomicU32,
    /// pushes skipped because the peer was busy.
    pub skipped: AtomicU64,
    /// pushes applied.
    pub applied: AtomicU64,
}

impl PushSumWeight {
    pub fn new(initial: f32) -> Self {
        PushSumWeight {
            w: AtomicU32::new(initial.to_bits()),
            busy: AtomicU32::new(0),
            skipped: AtomicU64::new(0),
            applied: AtomicU64::new(0),
        }
    }

    pub fn get(&self) -> f32 {
        f32::from_bits(self.w.load(Ordering::Relaxed))
    }

    pub fn set(&self, v: f32) {
        self.w.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Sender side: halve own weight, return the half being shipped.
    ///
    /// CAS loop on the bits: a plain `get`/`set` pair would silently
    /// overwrite a concurrent `try_accept`/`reclaim` deposit landing in
    /// between, destroying push-sum mass.
    pub fn halve(&self) -> f32 {
        let prev = self
            .w
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f32::from_bits(bits) * 0.5).to_bits())
            })
            .unwrap();
        f32::from_bits(prev) * 0.5
    }

    /// Receiver side: try to accept `w_in`; returns the mixing fraction
    /// `w_in / (w_self + w_in)` on success, or `None` if the slot was busy
    /// (skip-on-contention).
    pub fn try_accept(&self, w_in: f32) -> Option<f32> {
        if self
            .busy
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            self.skipped.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // the busy flag serializes accepts against each other and against
        // drains, but NOT against the owner's own `halve`/`reclaim` — the
        // deposit must be a CAS add so a concurrent halving never erases it
        let prev = self
            .w
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f32::from_bits(bits) + w_in).to_bits())
            })
            .unwrap();
        let w_self = f32::from_bits(prev);
        let frac = w_in / (w_self + w_in);
        self.applied.fetch_add(1, Ordering::Relaxed);
        Some(frac)
    }

    /// Release the busy slot after the parameter mix finished.
    pub fn release(&self) {
        self.busy.store(0, Ordering::Release);
    }

    /// Undo a `halve()` whose push was skipped: reclaim the shipped weight so
    /// total mass is conserved (CAS add, same reasoning as [`Self::halve`]).
    pub fn reclaim(&self, w_half: f32) {
        self.w
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f32::from_bits(bits) + w_half).to_bits())
            })
            .unwrap();
    }

    /// Atomically (w.r.t. the accept slot) drain the whole weight: claims
    /// the busy flag so a concurrent `try_accept` deposit cannot be lost to
    /// a read-zero-write race, zeroes the weight and returns it. `None`
    /// when the slot is busy — the caller retries later. Used by the chaos
    /// supervisor to fold a dead worker's weight into a survivor.
    pub fn try_drain(&self) -> Option<f32> {
        if self
            .busy
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return None;
        }
        // atomic swap-to-zero: a concurrent `halve` between a get/set pair
        // would let the drained mass AND the shipped half both survive
        let w = f32::from_bits(self.w.swap(0f32.to_bits(), Ordering::Relaxed));
        self.release();
        Some(w)
    }
}

/// Peer-selection strategies. The paper uses uniform random gossip; the ring
/// and grouped variants exist for the ablations discussed in Appendix B.2.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Uniform random peer each iteration (randomized gossip; default).
    Random,
    /// Fixed directed ring: i -> (i+1) mod M.
    Ring,
    /// Cascade groups as in Appendix B.2: peers chosen from the next group.
    Groups(usize),
}

/// The group that member `i` belongs to when `m` workers are split into `g`
/// contiguous groups: `⌊i·g/m⌋`. Consistent with [`group_bounds`] — member
/// `i` always falls inside its own group's range.
pub fn group_of(i: usize, m: usize, g: usize) -> usize {
    debug_assert!(g >= 1 && g <= m && i < m);
    i * g / m
}

/// Exact half-open bounds `[lo, hi)` of group `k` under the [`group_of`]
/// partition: `lo = ⌈k·m/g⌉`, `hi = ⌈(k+1)·m/g⌉`. For `g <= m` the ranges
/// partition `0..m` exactly and every group is non-empty — floor-based
/// bounds (the seed-era arithmetic) disagree with `⌊i·g/m⌋` membership and
/// can produce empty groups when `g ∤ m`.
pub fn group_bounds(k: usize, m: usize, g: usize) -> (usize, usize) {
    debug_assert!(g >= 1 && g <= m && k < g);
    let lo = (k * m + g - 1) / g;
    let hi = ((k + 1) * m + g - 1) / g;
    (lo, hi)
}

impl Topology {
    /// Choose the receiver for worker `me` at iteration `iter`.
    pub fn peer(&self, me: usize, m: usize, iter: u64, rng: &mut Pcg32) -> usize {
        match self {
            Topology::Random => rng.peer(me, m),
            Topology::Ring => (me + 1) % m,
            Topology::Groups(g) => {
                let g = (*g).max(1).min(m);
                if g == 1 {
                    // a single group degenerates to uniform random gossip
                    return rng.peer(me, m);
                }
                let mine = group_of(me, m, g);
                // cascade: cycle through every *other* group over iterations
                let next_group = (mine + 1 + (iter as usize % (g - 1))) % g;
                let (lo, hi) = group_bounds(next_group, m, g);
                // uniform member of the next group; `me` is never inside it
                // because next_group != mine and the bounds are exact
                lo + rng.below_usize(hi - lo)
            }
        }
    }
}

/// Probability that at least two of `m` workers pick the same receiver under
/// uniform random gossip — the contention rate the paper argues vanishes as M
/// grows. Used by tests and the DES.
pub fn collision_probability(m: usize) -> f64 {
    // Each of m senders picks among (m-1) receivers; birthday-style bound.
    if m < 2 {
        return 0.0;
    }
    let mut p_no = 1.0f64;
    for k in 0..m {
        p_no *= 1.0 - k as f64 / (m - 1) as f64;
        if p_no <= 0.0 {
            return 1.0;
        }
    }
    1.0 - p_no
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halve_then_accept_conserves_weight() {
        let a = PushSumWeight::new(0.5);
        let b = PushSumWeight::new(0.5);
        let shipped = a.halve();
        assert_eq!(shipped, 0.25);
        assert_eq!(a.get(), 0.25);
        let frac = b.try_accept(shipped).unwrap();
        b.release();
        assert!((frac - 0.25 / 0.75).abs() < 1e-6);
        assert!((a.get() + b.get() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn skip_on_contention_then_reclaim() {
        let b = PushSumWeight::new(0.5);
        let f1 = b.try_accept(0.1);
        assert!(f1.is_some()); // slot now busy
        let f2 = b.try_accept(0.2);
        assert!(f2.is_none(), "second concurrent push must be skipped");
        assert_eq!(b.skipped.load(Ordering::Relaxed), 1);
        b.release();

        // sender reclaims so global mass is conserved
        let a = PushSumWeight::new(0.15);
        let shipped = a.halve();
        a.reclaim(shipped);
        assert!((a.get() - 0.15).abs() < 1e-7);
    }

    #[test]
    fn try_drain_respects_the_accept_slot() {
        let w = PushSumWeight::new(0.5);
        // busy slot (a peer mid-deposit): drain backs off, weight untouched
        assert!(w.try_accept(0.125).is_some());
        assert!(w.try_drain().is_none());
        w.release();
        // free slot: the whole weight moves out exactly once
        assert_eq!(w.try_drain(), Some(0.625));
        assert_eq!(w.get(), 0.0);
        assert_eq!(w.try_drain(), Some(0.0), "second drain finds nothing");
    }

    #[test]
    fn random_topology_uniform_and_not_self() {
        let t = Topology::Random;
        let mut rng = Pcg32::new(3);
        let mut counts = [0usize; 8];
        for it in 0..80_000u64 {
            let j = t.peer(3, 8, it, &mut rng);
            assert_ne!(j, 3);
            counts[j] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            if i != 3 {
                assert!((10_000..13_000).contains(&c), "{counts:?}");
            }
        }
    }

    #[test]
    fn ring_topology() {
        let t = Topology::Ring;
        let mut rng = Pcg32::new(1);
        assert_eq!(t.peer(0, 4, 0, &mut rng), 1);
        assert_eq!(t.peer(3, 4, 0, &mut rng), 0);
    }

    /// Satellite stress for the CAS weight ops: one thread gossips a→b while
    /// another gossips b→a, so halvings race accepts on both cells. With the
    /// seed-era plain get/set read-modify-writes a deposit landing between
    /// the two halves of a halve (or vice versa) was silently overwritten,
    /// destroying ~0.1-scale chunks of push-sum mass; with `fetch_update`
    /// loops only f32 rounding (≪1e-3 over 40k ops) remains.
    #[test]
    fn concurrent_halve_vs_accept_conserves_mass() {
        use std::sync::Arc;
        let a = Arc::new(PushSumWeight::new(0.5));
        let b = Arc::new(PushSumWeight::new(0.5));
        let iters = 20_000usize;
        let gossip = |src: Arc<PushSumWeight>, dst: Arc<PushSumWeight>| {
            std::thread::spawn(move || {
                for _ in 0..iters {
                    let shipped = src.halve();
                    match dst.try_accept(shipped) {
                        Some(_) => dst.release(),
                        None => src.reclaim(shipped),
                    }
                }
            })
        };
        let t1 = gossip(a.clone(), b.clone());
        let t2 = gossip(b.clone(), a.clone());
        t1.join().unwrap();
        t2.join().unwrap();
        let total = a.get() as f64 + b.get() as f64;
        assert!(
            (total - 1.0).abs() < 1e-3,
            "push-sum mass not conserved under halve-vs-accept races: {total}"
        );
    }

    /// Property test over all (m, g) in 2..=16: the group bounds partition
    /// `0..m` exactly, every group is non-empty, membership agrees with the
    /// bounds, and `peer` always lands inside the cascade's next group
    /// (never on `me`). `g > m` clamps to `m` singleton groups.
    #[test]
    fn groups_partition_exactly_for_all_m_g() {
        for m in 2usize..=16 {
            for g in 2usize..=16 {
                let ge = g.min(m); // peer() clamps; config validation rejects
                let mut covered = 0usize;
                for k in 0..ge {
                    let (lo, hi) = group_bounds(k, m, ge);
                    assert!(lo < hi, "empty group k={k} m={m} g={ge}");
                    assert_eq!(lo, covered, "gap/overlap at k={k} m={m} g={ge}");
                    for i in lo..hi {
                        assert_eq!(group_of(i, m, ge), k, "member {i} m={m} g={ge}");
                    }
                    covered = hi;
                }
                assert_eq!(covered, m, "bounds must partition 0..{m} (g={ge})");

                let t = Topology::Groups(g);
                let mut rng = Pcg32::new((m * 31 + g) as u64);
                for me in 0..m {
                    for it in 0..64u64 {
                        let j = t.peer(me, m, it, &mut rng);
                        assert!(j < m);
                        assert_ne!(j, me, "m={m} g={g} me={me} it={it}");
                        let mine = group_of(me, m, ge);
                        let expected = (mine + 1 + (it as usize % (ge - 1))) % ge;
                        assert_eq!(
                            group_of(j, m, ge),
                            expected,
                            "peer left the cascade group: m={m} g={g} me={me}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn groups_topology_never_self() {
        let t = Topology::Groups(3);
        let mut rng = Pcg32::new(2);
        for me in 0..6 {
            for it in 0..2000u64 {
                let j = t.peer(me, 6, it, &mut rng);
                assert_ne!(j, me);
                assert!(j < 6);
            }
        }
    }

    #[test]
    fn collision_probability_decreases_then_small_world_sane() {
        assert_eq!(collision_probability(1), 0.0);
        let p2 = collision_probability(2);
        assert!(p2 > 0.99); // 2 workers always collide (each picks the other)
        // the *pairwise* collision chance for a specific pair is what decays;
        // sanity: probability is monotone in [0,1]
        for m in 2..32 {
            let p = collision_probability(m);
            assert!((0.0..=1.0).contains(&p));
        }
    }
}
