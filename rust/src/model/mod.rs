//! Layered model executor: drives the per-layer fwd/bwd HLO artifacts.
//!
//! The central LayUp hook is [`ModelExec::backward`]: it walks the layers in
//! *reverse* order and invokes the gradient sink **immediately after each
//! layer's backward artifact returns** — i.e. the moment that layer's
//! gradient exists — so the caller (a worker's training loop) can hand the
//! layer to its updater thread while the backward pass continues towards the
//! input. This is the "incremental layer-wise updates during backpropagation"
//! of the paper, with the activation cotangent `gx` threaded between
//! artifacts as a device literal (no host round-trip).
//!
//! Parameters live in shared lock-free stores ([`LayerParams`]); because
//! gossip can rewrite them *between* forward and backward (and even between
//! two layers of one pass — the paper's `x̂` vs `x̃` distinction), the
//! executor re-validates its upload cache against the layer's version
//! counter on every use rather than assuming the forward's snapshot is still
//! current.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::Batch;
use crate::manifest::{DType, LayerKind, Manifest, ModelManifest};
use crate::runtime::{self, Executable, Runtime};
use crate::tensor::clock::ClockStamp;
use crate::tensor::shard::ShardPool;
use crate::tensor::{AtomicTensor, LayerParams, Tensor};
use crate::util::rng::Pcg32;

/// Shared (across threads) parameter state of one worker's model replica.
pub struct ModelParams {
    pub layers: Vec<LayerParams>,
}

impl ModelParams {
    /// Initialize from the manifest's init specs with a per-worker seed.
    pub fn init(manifest: &ModelManifest, seed: u64) -> Arc<ModelParams> {
        let mut rng = Pcg32::new(seed);
        let layers = manifest
            .layers
            .iter()
            .map(|lm| {
                LayerParams::new(
                    lm.params
                        .iter()
                        .map(|p| {
                            let mut t = Tensor::zeros(&p.shape);
                            match p.init.as_str() {
                                "zeros" => {}
                                "ones" => t.fill(1.0),
                                "uniform" => {
                                    for v in &mut t.data {
                                        *v = (rng.next_f32() * 2.0 - 1.0) * p.scale;
                                    }
                                }
                                _ => {
                                    for v in &mut t.data {
                                        *v = rng.normal() * p.scale;
                                    }
                                }
                            }
                            AtomicTensor::from_tensor(&t)
                        })
                        .collect(),
                )
            })
            .collect();
        Arc::new(ModelParams { layers })
    }

    pub fn numel(&self) -> usize {
        self.layers.iter().map(|l| l.numel()).sum()
    }

    /// Flatten every parameter into one vector (drift / bias diagnostics).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.numel());
        for l in &self.layers {
            for t in &l.tensors {
                let snap = t.snapshot();
                out.extend_from_slice(&snap.data);
            }
        }
        out
    }

    /// Overwrite every parameter from a flat vector (inverse of `flatten`),
    /// stamping each layer's clock with `(worker, step)` provenance.
    pub fn store_flat(&self, flat: &[f32], worker: usize, step: usize) {
        self.store_flat_sharded(flat, worker, step, &ShardPool::serial());
    }

    /// [`ModelParams::store_flat`] with each tensor's copy sharded across
    /// `pool` (§Perf — the LocalSGD/SlowMo/CO2 collective write-back path).
    /// The clock protocol is unchanged: one stamp per layer per logical
    /// write, regardless of how many shards the stores split into.
    pub fn store_flat_sharded(&self, flat: &[f32], worker: usize, step: usize, pool: &ShardPool) {
        let mut off = 0;
        for l in &self.layers {
            for t in &l.tensors {
                let n = t.numel();
                t.store_from_sharded(&flat[off..off + n], pool);
                off += n;
            }
            l.clock.record(worker, step);
        }
        debug_assert_eq!(off, flat.len());
    }

    /// Copy all values from another replica (gossip rejoin / broadcast),
    /// stamping each layer's clock with the donor's `(worker, step)`.
    pub fn copy_from(&self, other: &ModelParams, worker: usize, step: usize) {
        for (a, b) in self.layers.iter().zip(&other.layers) {
            for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
                ta.store_from(&tb.snapshot().data);
            }
            a.clock.record(worker, step);
        }
    }

    /// Reader-side snapshot of every layer's staleness clock — the
    /// provenance of the parameters a forward pass is about to consume.
    /// The engine threads this through `StepState`/`HostPass` so the
    /// gradient-apply sites can compute the observed per-layer delay τ.
    pub fn clock_snapshot(&self) -> Vec<ClockStamp> {
        self.layers.iter().map(|l| l.clock.stamp()).collect()
    }

    /// Per-layer clock state for a checkpoint (restored by
    /// [`ModelParams::load_clocks`] bit-identically).
    pub fn clock_state(&self) -> Vec<ClockStamp> {
        self.clock_snapshot()
    }

    /// Restore exact per-layer clock state from a checkpoint. A count
    /// mismatch is rejected like any other shape mismatch — a silently
    /// partial restore would break resume bit-parity and mis-compute τ.
    pub fn load_clocks(&self, stamps: &[ClockStamp]) -> Result<()> {
        if stamps.len() != self.layers.len() {
            bail!(
                "checkpoint carries {} layer clocks, model has {} layers",
                stamps.len(),
                self.layers.len()
            );
        }
        for (l, &st) in self.layers.iter().zip(stamps) {
            l.clock.load(st);
        }
        Ok(())
    }

    /// Checkpoint view of the replica: `state[layer][tensor]` holds that
    /// parameter's values. Together with [`ModelParams::load_state_dict`]
    /// this is the `resilience::checkpoint` contract for model state.
    pub fn state_dict(&self) -> Vec<Vec<Vec<f32>>> {
        self.layers
            .iter()
            .map(|l| l.tensors.iter().map(|t| t.state_dict()).collect())
            .collect()
    }

    /// Restore every parameter from a [`ModelParams::state_dict`] snapshot.
    /// The snapshot must have been taken from a same-shaped model.
    pub fn load_state_dict(&self, state: &[Vec<Vec<f32>>]) -> Result<()> {
        if state.len() != self.layers.len() {
            bail!(
                "model state_dict has {} layers, model has {}",
                state.len(),
                self.layers.len()
            );
        }
        for (l, ls) in self.layers.iter().zip(state) {
            if ls.len() != l.tensors.len() {
                bail!("model state_dict layer tensor count mismatch");
            }
            for (t, ts) in l.tensors.iter().zip(ls) {
                if ts.len() != t.numel() {
                    bail!(
                        "model state_dict tensor has {} values, store holds {}",
                        ts.len(),
                        t.numel()
                    );
                }
                t.load_state_dict(ts);
            }
        }
        Ok(())
    }

    /// A fresh replica holding identical values. Cheaper than `init` +
    /// `copy_from` (no RNG draws, one pass per tensor) — `Shared::new` builds
    /// every worker's replica from one prototype this way.
    pub fn replica(&self) -> Arc<ModelParams> {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                LayerParams::new(
                    l.tensors.iter().map(|t| AtomicTensor::from_tensor(&t.snapshot())).collect(),
                )
            })
            .collect();
        Arc::new(ModelParams { layers })
    }
}

/// Upload cache entry: literals for one layer's params, keyed by version.
struct LayerLiteralCache {
    version: u64,
    literals: Vec<xla::Literal>,
    scratch: Vec<f32>,
}

struct LayerExec {
    fwd: Rc<Executable>,
    bwd: Rc<Executable>,
}

/// The result of one forward pass (kept for the matching backward).
pub struct ForwardPass {
    pub loss: f32,
    pub metric: f32,
    /// input literal of every layer: activations[i] feeds layer i
    activations: Vec<xla::Literal>,
    targets: xla::Literal,
}

/// A forward pass downloaded to host memory so it can cross threads.
///
/// `xla::Literal` is `!Send`, so the decoupled forward/backward pools cannot
/// ship a [`ForwardPass`] through the pass queue. A `HostPass` instead holds
/// every activation in plain reusable buffers: [`ModelExec::forward_host`]
/// fills one on a forward-pool thread, the bounded queue carries it, and
/// [`ModelExec::backward_host`] re-uploads the activations on a
/// backward-pool thread. Buffers are recycled across steps via the
/// coordinator's pass pool, so the steady-state round-trip costs host
/// memcpys but **no per-step allocation** on our side (§Perf).
#[derive(Default)]
pub struct HostPass {
    /// the training step this pass belongs to
    pub step: usize,
    pub loss: f32,
    pub metric: f32,
    /// model input (layer 0's x) in the dtype the first artifact expects
    x_f32: Vec<f32>,
    x_i32: Vec<i32>,
    /// downloaded activations: `acts[i]` feeds layer i. Index 0 is unused —
    /// the input lives in `x_f32`/`x_i32` because its dtype varies by model.
    acts: Vec<Tensor>,
    targets: Vec<i32>,
    /// per-layer staleness-clock snapshot taken when the pass read its
    /// parameters (filled by the forward pool; consumed into the backward
    /// pass's `StepState`)
    pub clocks: Vec<ClockStamp>,
    /// forward-time parameter values per layer (`x_then[layer][param]`) for
    /// DC-ASGD delay compensation; empty when `compensation = "none"`
    pub x_then: Vec<Vec<Tensor>>,
}

/// Thread-local executor for one model on one worker.
pub struct ModelExec {
    pub manifest: ModelManifest,
    /// artifacts directory this executor was loaded from (diagnostics)
    pub dir: std::path::PathBuf,
    layers: Vec<LayerExec>,
    cache: Vec<LayerLiteralCache>,
    /// cumulative compute accounting (drained by the worker for MFU)
    pub compute_s: f64,
    pub flops_retired: u64,
    /// uploads skipped thanks to version caching (perf counter)
    pub upload_hits: u64,
    pub upload_misses: u64,
}

impl ModelExec {
    /// Compile all (distinct) layer artifacts of `model_name`.
    pub fn load(rt: &mut Runtime, man: &Manifest, model_name: &str) -> Result<ModelExec> {
        let manifest = man.model(model_name)?.clone();
        let mut layers = Vec::with_capacity(manifest.layers.len());
        let mut cache = Vec::with_capacity(manifest.layers.len());
        for lm in &manifest.layers {
            let fwd = rt.load(&man.artifact_path(&lm.fwd_file))?;
            let bwd = rt.load(&man.artifact_path(&lm.bwd_file))?;
            layers.push(LayerExec { fwd, bwd });
            cache.push(LayerLiteralCache {
                version: u64::MAX,
                literals: Vec::new(),
                scratch: Vec::new(),
            });
        }
        Ok(ModelExec {
            manifest,
            dir: man.dir.clone(),
            layers,
            cache,
            compute_s: 0.0,
            flops_retired: 0,
            upload_hits: 0,
            upload_misses: 0,
        })
    }

    /// Refresh (if stale) and return the literal uploads of layer `li`.
    fn param_literals(&mut self, li: usize, params: &ModelParams) -> Result<()> {
        let lp = &params.layers[li];
        let ver = lp.version();
        let entry = &mut self.cache[li];
        if entry.version == ver && !entry.literals.is_empty() {
            self.upload_hits += 1;
            return Ok(());
        }
        self.upload_misses += 1;
        entry.literals.clear();
        for (t, spec) in lp.tensors.iter().zip(&self.manifest.layers[li].params) {
            entry.scratch.resize(t.numel(), 0.0);
            t.load_into(&mut entry.scratch);
            entry
                .literals
                .push(runtime::literal_f32(&spec.shape, &entry.scratch)?);
        }
        entry.version = ver;
        Ok(())
    }

    /// Drop the inputs jax DCE'd out of the artifact (manifest `*_kept`).
    fn filter_args<'a>(args: Vec<&'a xla::Literal>, kept: &[usize]) -> Vec<&'a xla::Literal> {
        if kept.len() == args.len() {
            return args;
        }
        kept.iter().map(|&i| args[i]).collect()
    }

    fn input_literal(&self, batch: &Batch) -> Result<xla::Literal> {
        let first = &self.manifest.layers[0];
        match first.x_dtype {
            DType::F32 => runtime::literal_f32(&first.x_shape, &batch.x_f32),
            DType::I32 => runtime::literal_i32(&first.x_shape, &batch.x_i32),
        }
    }

    fn targets_literal(&self, batch: &Batch) -> Result<xla::Literal> {
        let loss = self.manifest.layers.last().unwrap();
        let shape = loss
            .targets_shape
            .as_ref()
            .context("loss layer missing targets_shape")?;
        runtime::literal_i32(shape, &batch.targets)
    }

    /// Run the full forward pass; returns loss/metric plus the stashed
    /// activations needed by `backward`.
    pub fn forward(&mut self, params: &ModelParams, batch: &Batch) -> Result<ForwardPass> {
        let n = self.layers.len();
        let mut activations = Vec::with_capacity(n);
        activations.push(self.input_literal(batch)?);
        let targets = self.targets_literal(batch)?;

        for li in 0..n - 1 {
            self.param_literals(li, params)?;
            let entry = &self.cache[li];
            let mut args: Vec<&xla::Literal> = entry.literals.iter().collect();
            args.push(&activations[li]);
            let args = Self::filter_args(args, &self.manifest.layers[li].fwd_kept);
            let mut outs = self.layers[li].fwd.run(&args)?;
            if outs.len() != 1 {
                bail!("layer {li} fwd returned {} outputs", outs.len());
            }
            self.flops_retired += self.manifest.layers[li].fwd_flops;
            activations.push(outs.pop().unwrap());
        }

        // loss layer
        let li = n - 1;
        self.param_literals(li, params)?;
        let entry = &self.cache[li];
        let mut args: Vec<&xla::Literal> = entry.literals.iter().collect();
        args.push(&activations[li]);
        args.push(&targets);
        let args = Self::filter_args(args, &self.manifest.layers[li].fwd_kept);
        let outs = self.layers[li].fwd.run(&args)?;
        if outs.len() != 2 {
            bail!("loss layer returned {} outputs (want loss, metric)", outs.len());
        }
        self.flops_retired += self.manifest.layers[li].fwd_flops;
        let loss = runtime::literal_scalar_f32(&outs[0])?;
        let metric = runtime::literal_scalar_f32(&outs[1])?;
        self.drain_compute_time();
        Ok(ForwardPass { loss, metric, activations, targets })
    }

    /// Run the backward pass layer-by-layer in reverse, invoking
    /// `sink(layer_idx, grads)` the moment each layer's gradient exists.
    ///
    /// `grads` are host tensors in manifest param order. Parameter literals
    /// are re-validated per layer, so gossip writes landing mid-backward are
    /// picked up exactly as in the paper (the gradient may then be slightly
    /// biased — Lemma 6.1 bounds this).
    pub fn backward(
        &mut self,
        params: &ModelParams,
        pass: &ForwardPass,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> Result<()> {
        let n = self.layers.len();

        // loss layer: bwd(params, x, targets) -> (*gparams, gx)
        let li = n - 1;
        self.param_literals(li, params)?;
        let entry = &self.cache[li];
        let mut args: Vec<&xla::Literal> = entry.literals.iter().collect();
        args.push(&pass.activations[li]);
        args.push(&pass.targets);
        let args = Self::filter_args(args, &self.manifest.layers[li].bwd_kept);
        let mut outs = self.layers[li].bwd.run(&args)?;
        self.flops_retired += self.manifest.layers[li].bwd_flops;
        let mut gy = outs.pop().context("loss bwd missing gx")?;
        sink(li, self.grads_from(li, outs)?);

        // mid layers, then first
        for li in (0..n - 1).rev() {
            self.param_literals(li, params)?;
            let entry = &self.cache[li];
            let mut args: Vec<&xla::Literal> = entry.literals.iter().collect();
            args.push(&pass.activations[li]);
            args.push(&gy);
            let args = Self::filter_args(args, &self.manifest.layers[li].bwd_kept);
            let mut outs = self.layers[li].bwd.run(&args)?;
            self.flops_retired += self.manifest.layers[li].bwd_flops;
            if self.manifest.layers[li].kind != LayerKind::First {
                gy = outs.pop().context("mid bwd missing gx")?;
            }
            sink(li, self.grads_from(li, outs)?);
        }
        self.drain_compute_time();
        Ok(())
    }

    /// Run the full forward pass and download every activation into `out`'s
    /// reusable host buffers, so the pass can cross to a backward-pool
    /// thread. `out.step`/`out.loss`/`out.metric` are filled in; previously
    /// pooled buffer contents are overwritten in place.
    pub fn forward_host(
        &mut self,
        params: &ModelParams,
        batch: &Batch,
        out: &mut HostPass,
    ) -> Result<()> {
        let pass = self.forward(params, batch)?;
        out.loss = pass.loss;
        out.metric = pass.metric;
        let n = self.layers.len();
        if out.acts.len() != n {
            // First use of this pooled pass: shape the activation buffers.
            // Index 0 stays empty — the input lives in x_f32/x_i32 (dtype
            // varies by model), so no input-sized buffer is wasted on it.
            out.acts = self
                .manifest
                .layers
                .iter()
                .enumerate()
                .map(|(li, lm)| if li == 0 { Tensor::zeros(&[0]) } else { Tensor::zeros(&lm.x_shape) })
                .collect();
        }
        for li in 1..n {
            runtime::literal_read_f32_into(&pass.activations[li], &mut out.acts[li].data)
                .with_context(|| format!("downloading activation of layer {li}"))?;
        }
        out.x_f32.clear();
        out.x_f32.extend_from_slice(&batch.x_f32);
        out.x_i32.clear();
        out.x_i32.extend_from_slice(&batch.x_i32);
        out.targets.clear();
        out.targets.extend_from_slice(&batch.targets);
        Ok(())
    }

    /// Backward counterpart of [`forward_host`]: re-upload the host-side
    /// activations as literals and run the usual reverse layer walk, invoking
    /// `sink` per layer exactly like [`backward`]. Parameter literals are
    /// still re-validated per layer, so gossip writes landing between the
    /// (possibly remote-thread) forward and this backward are picked up —
    /// the paper's `x̂` vs `x̃` staleness, bounded by Lemma 6.1.
    pub fn backward_host(
        &mut self,
        params: &ModelParams,
        pass: &HostPass,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> Result<()> {
        let n = self.layers.len();
        if pass.acts.len() != n {
            bail!("HostPass has {} activations, model has {n} layers", pass.acts.len());
        }
        let first = &self.manifest.layers[0];
        let mut activations = Vec::with_capacity(n);
        activations.push(match first.x_dtype {
            DType::F32 => runtime::literal_f32(&first.x_shape, &pass.x_f32)?,
            DType::I32 => runtime::literal_i32(&first.x_shape, &pass.x_i32)?,
        });
        for li in 1..n {
            activations.push(runtime::literal_f32(
                &self.manifest.layers[li].x_shape,
                &pass.acts[li].data,
            )?);
        }
        let loss = self.manifest.layers.last().unwrap();
        let shape = loss
            .targets_shape
            .as_ref()
            .context("loss layer missing targets_shape")?;
        let targets = runtime::literal_i32(shape, &pass.targets)?;
        let fp = ForwardPass { loss: pass.loss, metric: pass.metric, activations, targets };
        self.backward(params, &fp, sink)
    }

    fn grads_from(&self, li: usize, outs: Vec<xla::Literal>) -> Result<Vec<Tensor>> {
        let specs = &self.manifest.layers[li].params;
        if outs.len() != specs.len() {
            bail!(
                "layer {li} bwd returned {} grads, manifest says {}",
                outs.len(),
                specs.len()
            );
        }
        outs.iter()
            .zip(specs)
            .map(|(lit, spec)| {
                Ok(Tensor::from_vec(&spec.shape, runtime::literal_to_vec_f32(lit)?))
            })
            .collect()
    }

    /// Pull per-executable timing into the cumulative counter.
    fn drain_compute_time(&mut self) {
        let mut total = 0.0;
        for l in &self.layers {
            total += *l.fwd.exec_seconds.borrow() + *l.bwd.exec_seconds.borrow();
            *l.fwd.exec_seconds.borrow_mut() = 0.0;
            *l.bwd.exec_seconds.borrow_mut() = 0.0;
        }
        self.compute_s += total;
    }

    /// Evaluate on `k` deterministic held-out batches; returns
    /// (mean loss, accuracy in [0,1]). A dataset with no eval batches is an
    /// error — the old `.min(eval_len()).max(1)` clamp would have requested
    /// batch 0 of an empty eval set.
    pub fn evaluate(
        &mut self,
        params: &ModelParams,
        data: &dyn crate::data::Dataset,
        k: usize,
    ) -> Result<(f64, f64)> {
        if data.eval_len() == 0 {
            anyhow::bail!("evaluate: dataset exposes no eval batches");
        }
        let k = k.min(data.eval_len()).max(1);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        let denom = self.examples_per_batch() as f64;
        for i in 0..k {
            let b = data.eval_batch(i);
            let pass = self.forward(params, &b)?;
            loss_sum += pass.loss as f64;
            correct += pass.metric as f64;
            total += denom;
        }
        Ok((loss_sum / k as f64, correct / total))
    }

    /// How many prediction events one batch contains (rows for vision,
    /// tokens for LM — matches the loss layer's `metric` semantics).
    pub fn examples_per_batch(&self) -> usize {
        let loss = self.manifest.layers.last().unwrap();
        loss.targets_shape
            .as_ref()
            .map(|s| s.iter().product())
            .unwrap_or(self.manifest.batch)
    }

    /// Per-step FLOPs (fwd+bwd over all layers).
    pub fn step_flops(&self) -> u64 {
        self.manifest.step_flops()
    }
}
