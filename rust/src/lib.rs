#![deny(clippy::all)]
//! # layup — asynchronous decentralized SGD with layer-wise updates
//!
//! A production-shaped reproduction of *"LAYUP: Asynchronous decentralized
//! gradient descent with LAYer-wise UPdates"* as a three-layer Rust+JAX+Pallas
//! stack:
//!
//! * **L1/L2 (build time)**: Pallas kernels + layered JAX models are AOT-lowered
//!   to per-layer HLO-text artifacts by `python/compile/aot.py`.
//! * **L3 (this crate)**: the distributed training coordinator. Worker threads
//!   execute the per-layer artifacts through PJRT ([`runtime`]); *updater*
//!   threads apply lock-free, layer-wise, randomized-gossip push-sum updates
//!   ([`algorithms`]) concurrently with the training loop, exactly as
//!   in the paper's Algorithm 1. DDP / GoSGD / AD-PSGD / SlowMo / CO2 /
//!   Local-SGD baselines run in the same harness for the paper's tables.
//!
//! The public entry point is [`session`]: build a [`session::Session`] from
//! a [`config::TrainConfig`] + [`manifest::Manifest`], attach typed-event
//! observers, run, get a [`metrics::RunSummary`].
//!
//! See `DESIGN.md` for the system inventory and the experiment index mapping
//! each paper table/figure to a bench target, and `EXPERIMENTS.md` for the
//! measured reproduction.

pub mod algorithms;
pub mod bias;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod manifest;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod resilience;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod telemetry;
pub mod tensor;
pub mod topology;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$LAYUP_ARTIFACTS` or ./artifacts,
/// walking up from the current dir so tests/benches work from target/.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LAYUP_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
