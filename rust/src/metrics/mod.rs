//! Metrics: learning curves, time-to-convergence / time-to-accuracy, model
//! FLOPs utilization, and the drift / gradient-bias trackers that validate
//! the paper's theory (Fig A1, Lemma 6.1).

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// One evaluation point on a learning curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// global step at which this evaluation ran
    pub step: usize,
    /// wall-clock (or virtual, for DES) seconds since training start
    pub time_s: f64,
    /// mean eval loss (NLL for LM -> perplexity = exp(loss))
    pub loss: f64,
    /// eval accuracy in [0, 1] (token accuracy for LM)
    pub accuracy: f64,
}

impl CurvePoint {
    pub fn perplexity(&self) -> f64 {
        self.loss.exp()
    }
}

/// Learning curve + convergence detection for one (algorithm, worker) run.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Restore step order. Decoupled-mode passes complete out of order, so
    /// eval points can be pushed non-monotonically; TTA/TTC scans and the
    /// CSV/JSON emitters assume step-sorted points.
    pub fn sort_by_step(&mut self) {
        self.points.sort_by_key(|p| p.step);
    }

    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    pub fn best_loss(&self) -> f64 {
        self.points.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min)
    }

    /// Time to reach `target` accuracy (TTA, Table 2). `None` if never reached.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy >= target).map(|p| p.time_s)
    }

    pub fn step_to_accuracy(&self, target: f64) -> Option<usize> {
        self.points.iter().find(|p| p.accuracy >= target).map(|p| p.step)
    }

    /// Time to convergence (TTC, Table 1): the first point whose accuracy is
    /// within `tol` of the run's best — i.e. when the curve flattens.
    pub fn time_to_convergence(&self, tol: f64) -> Option<f64> {
        let best = self.best_accuracy();
        self.points
            .iter()
            .find(|p| p.accuracy >= best - tol)
            .map(|p| p.time_s)
    }

    /// Loss-based TTC for LM tasks.
    pub fn time_to_loss_convergence(&self, tol: f64) -> Option<f64> {
        let best = self.best_loss();
        self.points
            .iter()
            .find(|p| p.loss <= best + tol)
            .map(|p| p.time_s)
    }

    pub fn to_json(&self) -> Json {
        arr(self
            .points
            .iter()
            .map(|p| {
                obj(vec![
                    ("step", num(p.step as f64)),
                    ("time_s", num(p.time_s)),
                    ("loss", num(p.loss)),
                    ("accuracy", num(p.accuracy)),
                ])
            })
            .collect())
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,time_s,loss,accuracy,perplexity\n");
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.3},{:.5},{:.5},{:.3}\n",
                p.step,
                p.time_s,
                p.loss,
                p.accuracy,
                p.perplexity()
            ));
        }
        out
    }
}

/// Model FLOPs Utilization (Table 4). `peak_flops_per_s` is the calibrated
/// single-worker compute-only throughput (the "theoretical peak" of our
/// substrate); `achieved` counts FLOPs actually retired over wall time, so
/// synchronization stalls and communication pauses lower MFU exactly as they
/// do on the paper's GPUs.
#[derive(Clone, Debug)]
pub struct MfuTracker {
    pub flops_retired: u64,
    pub wall_start: Option<Instant>,
    pub wall_s: f64,
    /// time actually spent inside compute (fwd+bwd execution)
    pub compute_s: f64,
}

impl Default for MfuTracker {
    fn default() -> Self {
        MfuTracker { flops_retired: 0, wall_start: None, wall_s: 0.0, compute_s: 0.0 }
    }
}

impl MfuTracker {
    pub fn start(&mut self) {
        self.wall_start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.wall_start.take() {
            self.wall_s += t0.elapsed().as_secs_f64();
        }
    }

    pub fn record_compute(&mut self, flops: u64, seconds: f64) {
        self.flops_retired += flops;
        self.compute_s += seconds;
    }

    /// Achieved FLOPs/s over wall time.
    pub fn achieved_flops_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.flops_retired as f64 / self.wall_s
    }

    /// MFU relative to a given peak.
    pub fn mfu(&self, peak_flops_per_s: f64) -> f64 {
        if peak_flops_per_s <= 0.0 {
            return 0.0;
        }
        self.achieved_flops_per_s() / peak_flops_per_s
    }

    /// Fraction of wall time spent computing (the occupancy view of MFU).
    pub fn compute_occupancy(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        (self.compute_s / self.wall_s).min(1.0)
    }
}

/// Depth/backpressure statistics of one decoupled pass queue (§Perf):
/// surfaces whether the forward pool outruns the backward pool (depth pinned
/// at capacity, pushes blocking) or starves it (depth near zero).
#[derive(Clone, Debug, Default)]
pub struct QueueStats {
    pub pushes: u64,
    pub pops: u64,
    /// pushes that had to wait at least once for space (backpressure events)
    pub blocked_pushes: u64,
    /// sum over pushes of the queue depth right after insertion
    pub depth_sum: u64,
    pub max_depth: usize,
}

impl QueueStats {
    /// Mean queue depth observed at push time.
    pub fn mean_depth(&self) -> f64 {
        if self.pushes == 0 {
            return 0.0;
        }
        self.depth_sum as f64 / self.pushes as f64
    }

    /// Fraction of pushes that hit backpressure.
    pub fn blocked_frac(&self) -> f64 {
        if self.pushes == 0 {
            return 0.0;
        }
        self.blocked_pushes as f64 / self.pushes as f64
    }

    /// Fold another queue's counters in (per-worker queues -> run totals).
    pub fn merge(&mut self, other: &QueueStats) {
        self.pushes += other.pushes;
        self.pops += other.pops;
        self.blocked_pushes += other.blocked_pushes;
        self.depth_sum += other.depth_sum;
        self.max_depth = self.max_depth.max(other.max_depth);
    }
}

/// Traffic counters of one directed fabric link (sender -> receiver).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkTraffic {
    /// sending worker
    pub from: usize,
    /// receiving worker
    pub to: usize,
    /// messages sent (including dropped ones)
    pub msgs: u64,
    /// encoded wire bytes sent (`Payload::encoded_len` after the fabric's
    /// codec ran — a sparsifying codec shrinks this, not the payload count)
    pub bytes: u64,
    /// messages the link dropped
    pub drops: u64,
    /// messages applied at the receiver
    pub delivered: u64,
}

/// Aggregated communication-fabric statistics of one run (per-link traffic
/// plus delivered-staleness), snapshotted from the fabric's counters.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// messages pushed onto the fabric (including dropped ones)
    pub msgs_sent: u64,
    /// encoded wire bytes pushed onto the fabric (post-codec
    /// `Payload::encoded_len` — the number `fig_compression` compares)
    pub bytes_sent: u64,
    /// messages the links dropped
    pub msgs_dropped: u64,
    /// messages applied at their receiver
    pub msgs_delivered: u64,
    /// sum over delivered messages of (receiver step - sender step)
    pub staleness_sum: i64,
    /// `StepFrame` messages shipped by the coalescing path (0 with
    /// `coalesce = false`); each one replaces `frame_layers / frames_sent`
    /// standalone layer pushes on the wire
    pub frames_sent: u64,
    /// layer pushes aggregated into those frames
    pub frame_layers: u64,
    /// per-link breakdown (links with traffic only, ordered by sender then
    /// receiver)
    pub links: Vec<LinkTraffic>,
}

impl CommStats {
    /// Mean steps a delivered message spent in flight (0 when nothing was
    /// delivered; 0 on the instant transport by definition).
    pub fn mean_delivered_staleness(&self) -> f64 {
        if self.msgs_delivered == 0 {
            return 0.0;
        }
        self.staleness_sum as f64 / self.msgs_delivered as f64
    }

    /// Fraction of sent messages the links dropped.
    pub fn drop_frac(&self) -> f64 {
        if self.msgs_sent == 0 {
            return 0.0;
        }
        self.msgs_dropped as f64 / self.msgs_sent as f64
    }
}

/// Histogram bucket count for observed per-layer staleness τ. Buckets:
/// `0, 1, 2, 3–4, 5–8, 9–16, 17–32, 33+` intervening writes.
pub const STALENESS_BUCKETS: usize = 8;

/// Upper-inclusive bucket labels (stable JSON/CSV vocabulary).
pub const STALENESS_BUCKET_LABELS: [&str; STALENESS_BUCKETS] =
    ["0", "1", "2", "3-4", "5-8", "9-16", "17-32", "33+"];

fn staleness_bucket(tau: u64) -> usize {
    match tau {
        0 => 0,
        1 => 1,
        2 => 2,
        3..=4 => 3,
        5..=8 => 4,
        9..=16 => 5,
        17..=32 => 6,
        _ => 7,
    }
}

/// Observed-staleness counters of one layer: how stale were the parameters
/// each applied gradient was computed against, in intervening writes τ
/// (see `crate::tensor::clock`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerStaleness {
    /// layer index
    pub layer: usize,
    /// gradient applies observed (τ recorded once per apply)
    pub applies: u64,
    /// Σ τ over applies
    pub tau_sum: u64,
    /// max τ observed
    pub tau_max: u64,
    /// histogram over [`STALENESS_BUCKET_LABELS`]
    pub hist: [u64; STALENESS_BUCKETS],
}

impl LayerStaleness {
    /// Mean observed τ (0 when nothing was applied).
    pub fn mean_tau(&self) -> f64 {
        if self.applies == 0 {
            return 0.0;
        }
        self.tau_sum as f64 / self.applies as f64
    }
}

/// Per-layer staleness histograms of one run (`RunStats::staleness`).
#[derive(Clone, Debug, Default)]
pub struct StalenessStats {
    /// one entry per model layer, in layer order
    pub layers: Vec<LayerStaleness>,
}

impl StalenessStats {
    /// Total gradient applies observed across layers.
    pub fn total_applies(&self) -> u64 {
        self.layers.iter().map(|l| l.applies).sum()
    }

    /// Mean observed τ across all layers' applies.
    pub fn mean_tau(&self) -> f64 {
        let applies = self.total_applies();
        if applies == 0 {
            return 0.0;
        }
        self.layers.iter().map(|l| l.tau_sum).sum::<u64>() as f64 / applies as f64
    }

    /// Max observed τ across layers.
    pub fn max_tau(&self) -> u64 {
        self.layers.iter().map(|l| l.tau_max).fold(0, u64::max)
    }
}

/// Lock-free run-time collector behind [`StalenessStats`]: one set of
/// atomic counters per layer, recorded by every gradient-apply site (LayUp's
/// updater threads, the stash algorithms' step-end loops) and snapshotted
/// into the summary.
pub struct StalenessTracker {
    layers: Vec<LayerStalenessCounters>,
}

#[derive(Default)]
struct LayerStalenessCounters {
    applies: std::sync::atomic::AtomicU64,
    tau_sum: std::sync::atomic::AtomicU64,
    tau_max: std::sync::atomic::AtomicU64,
    hist: [std::sync::atomic::AtomicU64; STALENESS_BUCKETS],
}

impl StalenessTracker {
    /// A tracker for an `n_layers`-layer model.
    pub fn new(n_layers: usize) -> StalenessTracker {
        StalenessTracker {
            layers: (0..n_layers).map(|_| LayerStalenessCounters::default()).collect(),
        }
    }

    /// Record one gradient apply on `layer` with observed staleness `tau`.
    pub fn record(&self, layer: usize, tau: u64) {
        use std::sync::atomic::Ordering::Relaxed;
        let Some(l) = self.layers.get(layer) else {
            return;
        };
        l.applies.fetch_add(1, Relaxed);
        l.tau_sum.fetch_add(tau, Relaxed);
        l.tau_max.fetch_max(tau, Relaxed);
        l.hist[staleness_bucket(tau)].fetch_add(1, Relaxed);
    }

    /// Snapshot the counters into a summary-ready [`StalenessStats`].
    pub fn snapshot(&self) -> StalenessStats {
        use std::sync::atomic::Ordering::Relaxed;
        StalenessStats {
            layers: self
                .layers
                .iter()
                .enumerate()
                .map(|(layer, l)| LayerStaleness {
                    layer,
                    applies: l.applies.load(Relaxed),
                    tau_sum: l.tau_sum.load(Relaxed),
                    tau_max: l.tau_max.load(Relaxed),
                    hist: std::array::from_fn(|b| l.hist[b].load(Relaxed)),
                })
                .collect(),
        }
    }
}

/// Model disagreement across workers (Fig A1): mean over workers of
/// ‖x_i − x̄‖ / √d, sampled during training.
#[derive(Clone, Debug, Default)]
pub struct DriftTracker {
    /// (step, disagreement)
    pub samples: Vec<(usize, f64)>,
}

impl DriftTracker {
    /// `flat_params[i]` is worker i's full parameter vector (flattened).
    pub fn record(&mut self, step: usize, flat_params: &[Vec<f32>]) {
        let m = flat_params.len();
        if m == 0 {
            return;
        }
        let d = flat_params[0].len();
        let mut mean = vec![0.0f64; d];
        for w in flat_params {
            for (mu, &x) in mean.iter_mut().zip(w.iter()) {
                *mu += x as f64;
            }
        }
        for mu in &mut mean {
            *mu /= m as f64;
        }
        let mut total = 0.0;
        for w in flat_params {
            let mut sq = 0.0;
            for (&x, &mu) in w.iter().zip(mean.iter()) {
                let dd = x as f64 - mu;
                sq += dd * dd;
            }
            total += (sq / d as f64).sqrt();
        }
        self.samples.push((step, total / m as f64));
    }

    /// Record a pre-computed disagreement sample. The §Perf streamed path
    /// (`coordinator`'s per-layer sweep over reusable buffers) computes the
    /// same ‖x_i − x̄‖ decomposed tensor-by-tensor instead of materializing
    /// every replica's full flattened parameter vector.
    pub fn push_sample(&mut self, step: usize, disagreement: f64) {
        self.samples.push((step, disagreement));
    }

    /// Restore step order (decoupled-mode samples can land out of order;
    /// `final_disagreement` and the CSV assume step-sorted samples).
    pub fn sort_by_step(&mut self) {
        self.samples.sort_by_key(|&(step, _)| step);
    }

    pub fn max_disagreement(&self) -> f64 {
        self.samples.iter().map(|&(_, v)| v).fold(0.0, f64::max)
    }

    pub fn final_disagreement(&self) -> f64 {
        self.samples.last().map(|&(_, v)| v).unwrap_or(0.0)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,disagreement\n");
        for (s, v) in &self.samples {
            out.push_str(&format!("{s},{v:.6}\n"));
        }
        out
    }
}

/// Fault-tolerance counters of one run (resilience subsystem): the chaos
/// timeline a summary carries alongside the loss curve.
#[derive(Clone, Debug, Default)]
pub struct RecoveryStats {
    /// workers torn down by the chaos schedule
    pub crashes: u64,
    /// workers respawned and rejoined
    pub joins: u64,
    /// periodic checkpoints written
    pub checkpoints_saved: u64,
    /// final membership epoch (0 when membership never changed)
    pub membership_epoch: u64,
    /// true when a Stall-policy collective waited past the stall timeout
    /// for a permanently lost worker and the run was stopped
    pub stalled: bool,
}

/// Parameter-server counters of one run (role topologies): zeros for flat
/// gossip runs, where no worker is a shard.
#[derive(Clone, Debug, Default)]
pub struct PsStats {
    /// server shards in the topology (`ps:N` → N; 0 when flat/hier)
    pub shards: u64,
    /// gradient pushes applied by the shards
    pub grad_pushes: u64,
    /// parameter replies shipped back to trainers
    pub param_pulls: u64,
    /// layer-partition reassignments after shard loss (Shrink policy)
    pub repartitions: u64,
    /// peak shard inbox depth observed by the shard drivers
    pub queue_depth_max: u64,
}

/// Typed per-run statistics — the replacement for the seed-era stringly
/// `extras: BTreeMap<String, f64>` map. Every field is still emitted under
/// its old key in the summary JSON, so downstream result files keep parsing.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// FLOPs actually retired over wall time (the MFU numerator)
    pub achieved_flops_per_s: f64,
    /// peak model disagreement across workers (Fig A1)
    pub max_disagreement: f64,
    /// disagreement at the last drift sample
    pub final_disagreement: f64,
    /// fraction of parameter uploads served from the version cache
    pub upload_hit_rate: f64,
    /// forward-side compute occupancy (per-pool split, §Perf)
    pub fwd_occupancy: f64,
    /// backward-side compute occupancy
    pub bwd_occupancy: f64,
    /// merged pass-queue counters (decoupled mode; zeros for serial runs)
    pub queue: QueueStats,
    /// communication-fabric traffic and delivered-staleness counters
    pub comm: CommStats,
    /// per-layer parameter-staleness histograms (observed τ at apply)
    pub staleness: StalenessStats,
    /// fault-tolerance counters (crashes, joins, checkpoints, stall flag)
    pub recovery: RecoveryStats,
    /// parameter-server counters (zeros outside `ps:N` topologies)
    pub ps: PsStats,
    /// span-tracing summary (all zeros unless `[telemetry]` is enabled)
    pub telemetry: crate::telemetry::TelemetryStats,
}

impl RunStats {
    /// Flat (key, value) view under the legacy `extras` key names.
    pub fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("achieved_flops_per_s", self.achieved_flops_per_s),
            ("max_disagreement", self.max_disagreement),
            ("final_disagreement", self.final_disagreement),
            ("upload_hit_rate", self.upload_hit_rate),
            ("fwd_occupancy", self.fwd_occupancy),
            ("bwd_occupancy", self.bwd_occupancy),
            ("queue_depth_mean", self.queue.mean_depth()),
            ("queue_depth_max", self.queue.max_depth as f64),
            ("queue_blocked_frac", self.queue.blocked_frac()),
            ("comm_msgs_sent", self.comm.msgs_sent as f64),
            ("comm_bytes_sent", self.comm.bytes_sent as f64),
            ("comm_dropped", self.comm.msgs_dropped as f64),
            ("comm_delivered", self.comm.msgs_delivered as f64),
            ("comm_mean_staleness", self.comm.mean_delivered_staleness()),
            ("comm_frames_sent", self.comm.frames_sent as f64),
            ("comm_frame_layers", self.comm.frame_layers as f64),
            ("stale_applies", self.staleness.total_applies() as f64),
            ("stale_tau_mean", self.staleness.mean_tau()),
            ("stale_tau_max", self.staleness.max_tau() as f64),
            ("recovery_crashes", self.recovery.crashes as f64),
            ("recovery_joins", self.recovery.joins as f64),
            ("checkpoints_saved", self.recovery.checkpoints_saved as f64),
            ("membership_epoch", self.recovery.membership_epoch as f64),
            ("stalled", if self.recovery.stalled { 1.0 } else { 0.0 }),
            ("ps_shards", self.ps.shards as f64),
            ("ps_grad_pushes", self.ps.grad_pushes as f64),
            ("ps_param_pulls", self.ps.param_pulls as f64),
            ("ps_repartitions", self.ps.repartitions as f64),
            ("ps_queue_depth_max", self.ps.queue_depth_max as f64),
            ("telemetry_spans", self.telemetry.spans as f64),
            ("telemetry_dropped", self.telemetry.dropped as f64),
        ]
    }
}

/// Summary for one algorithm run — what the paper's tables report.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub algorithm: String,
    pub curve: Curve,
    pub mfu: f64,
    pub compute_occupancy: f64,
    pub total_time_s: f64,
    pub total_steps: usize,
    pub epochs: usize,
    pub gossip_skipped: u64,
    pub gossip_applied: u64,
    pub stats: RunStats,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("algorithm", s(&self.algorithm)),
            ("curve", self.curve.to_json()),
            ("mfu", num(self.mfu)),
            ("compute_occupancy", num(self.compute_occupancy)),
            ("total_time_s", num(self.total_time_s)),
            ("total_steps", num(self.total_steps as f64)),
            ("epochs", num(self.epochs as f64)),
            ("gossip_skipped", num(self.gossip_skipped as f64)),
            ("gossip_applied", num(self.gossip_applied as f64)),
        ];
        for (k, v) in self.stats.fields() {
            fields.push((k, num(v)));
        }
        // per-layer staleness histograms (layers with applies only)
        fields.push((
            "staleness_layers",
            arr(self
                .stats
                .staleness
                .layers
                .iter()
                .filter(|l| l.applies > 0)
                .map(|l| {
                    obj(vec![
                        ("layer", num(l.layer as f64)),
                        ("applies", num(l.applies as f64)),
                        ("tau_mean", num(l.mean_tau())),
                        ("tau_max", num(l.tau_max as f64)),
                        (
                            "hist",
                            arr(l.hist.iter().map(|&c| num(c as f64)).collect()),
                        ),
                    ])
                })
                .collect()),
        ));
        // per-link traffic breakdown (nonzero links only)
        fields.push((
            "links",
            arr(self
                .stats
                .comm
                .links
                .iter()
                .map(|l| {
                    obj(vec![
                        ("from", num(l.from as f64)),
                        ("to", num(l.to as f64)),
                        ("msgs", num(l.msgs as f64)),
                        ("bytes", num(l.bytes as f64)),
                        ("drops", num(l.drops as f64)),
                        ("delivered", num(l.delivered as f64)),
                    ])
                })
                .collect()),
        ));
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(usize, f64, f64, f64)]) -> Curve {
        Curve {
            points: points
                .iter()
                .map(|&(step, time_s, loss, accuracy)| CurvePoint { step, time_s, loss, accuracy })
                .collect(),
        }
    }

    #[test]
    fn tta_finds_first_crossing() {
        let c = curve(&[(0, 0.0, 2.0, 0.1), (10, 1.0, 1.0, 0.5), (20, 2.0, 0.5, 0.7)]);
        assert_eq!(c.time_to_accuracy(0.5), Some(1.0));
        assert_eq!(c.step_to_accuracy(0.65), Some(20));
        assert_eq!(c.time_to_accuracy(0.9), None);
    }

    #[test]
    fn ttc_flattening() {
        let c = curve(&[
            (0, 0.0, 2.0, 0.10),
            (10, 1.0, 1.0, 0.60),
            (20, 2.0, 0.9, 0.69),
            (30, 3.0, 0.8, 0.70),
        ]);
        // best = 0.70; within 0.02 first at t=2.0
        assert_eq!(c.time_to_convergence(0.02), Some(2.0));
    }

    #[test]
    fn mfu_accounting() {
        let mut m = MfuTracker::default();
        m.wall_s = 2.0;
        m.record_compute(1_000_000, 1.0);
        assert_eq!(m.achieved_flops_per_s(), 500_000.0);
        assert!((m.mfu(1_000_000.0) - 0.5).abs() < 1e-9);
        assert!((m.compute_occupancy() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn drift_zero_when_identical_positive_when_not() {
        let mut d = DriftTracker::default();
        d.record(0, &[vec![1.0, 2.0], vec![1.0, 2.0]]);
        assert!(d.samples[0].1 < 1e-12);
        d.record(1, &[vec![0.0, 0.0], vec![2.0, 2.0]]);
        assert!(d.samples[1].1 > 0.9); // each worker is distance 1 (per-dim rms) from mean
    }

    #[test]
    fn queue_stats_mean_blocked_and_merge() {
        let mut a = QueueStats {
            pushes: 4,
            pops: 4,
            blocked_pushes: 1,
            depth_sum: 8,
            max_depth: 3,
        };
        assert!((a.mean_depth() - 2.0).abs() < 1e-12);
        assert!((a.blocked_frac() - 0.25).abs() < 1e-12);
        let b = QueueStats { pushes: 4, pops: 2, blocked_pushes: 3, depth_sum: 4, max_depth: 5 };
        a.merge(&b);
        assert_eq!(a.pushes, 8);
        assert_eq!(a.max_depth, 5);
        assert!((a.mean_depth() - 1.5).abs() < 1e-12);
        assert_eq!(QueueStats::default().mean_depth(), 0.0);
        assert_eq!(QueueStats::default().blocked_frac(), 0.0);
    }

    #[test]
    fn drift_push_sample_matches_record_semantics() {
        let mut a = DriftTracker::default();
        a.record(3, &[vec![0.0, 0.0], vec![2.0, 2.0]]);
        let mut b = DriftTracker::default();
        b.push_sample(3, a.samples[0].1);
        assert_eq!(a.samples, b.samples);
        assert_eq!(b.max_disagreement(), a.max_disagreement());
    }

    #[test]
    fn csv_and_json_emit() {
        let c = curve(&[(0, 0.0, 1.0, 0.5)]);
        assert!(c.to_csv().contains("0,0.000,1.00000,0.50000"));
        let j = c.to_json().dump();
        assert!(j.contains("\"accuracy\":0.5"));
    }

    #[test]
    fn staleness_tracker_buckets_and_snapshot() {
        let t = StalenessTracker::new(2);
        // layer 0: τ = 0, 1, 40 ; layer 1: τ = 6
        t.record(0, 0);
        t.record(0, 1);
        t.record(0, 40);
        t.record(1, 6);
        t.record(9, 3); // out-of-range layer is ignored, not a panic
        let s = t.snapshot();
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.layers[0].applies, 3);
        assert_eq!(s.layers[0].tau_sum, 41);
        assert_eq!(s.layers[0].tau_max, 40);
        assert_eq!(s.layers[0].hist[0], 1, "τ=0 bucket");
        assert_eq!(s.layers[0].hist[1], 1, "τ=1 bucket");
        assert_eq!(s.layers[0].hist[7], 1, "33+ bucket");
        assert_eq!(s.layers[1].hist[4], 1, "5-8 bucket");
        assert_eq!(s.total_applies(), 4);
        assert!((s.mean_tau() - 47.0 / 4.0).abs() < 1e-12);
        assert_eq!(s.max_tau(), 40);
        assert!((s.layers[1].mean_tau() - 6.0).abs() < 1e-12);
        // buckets cover every τ exactly once
        for tau in 0..200 {
            assert!(staleness_bucket(tau) < STALENESS_BUCKETS);
        }
        assert_eq!(staleness_bucket(2), 2);
        assert_eq!(staleness_bucket(4), 3);
        assert_eq!(staleness_bucket(5), 4);
        assert_eq!(staleness_bucket(16), 5);
        assert_eq!(staleness_bucket(17), 6);
        assert_eq!(staleness_bucket(33), 7);
    }

    #[test]
    fn staleness_layers_serialize_into_the_summary_json() {
        let stats = RunStats {
            staleness: StalenessStats {
                layers: vec![LayerStaleness {
                    layer: 1,
                    applies: 4,
                    tau_sum: 8,
                    tau_max: 5,
                    hist: [1, 1, 0, 1, 1, 0, 0, 0],
                }],
            },
            ..Default::default()
        };
        let summary = RunSummary {
            algorithm: "LayUp".into(),
            curve: Curve::default(),
            mfu: 0.5,
            compute_occupancy: 0.5,
            total_time_s: 1.0,
            total_steps: 10,
            epochs: 1,
            gossip_skipped: 0,
            gossip_applied: 0,
            stats,
        };
        let j = summary.to_json().dump();
        assert!(j.contains("\"stale_tau_mean\":2"), "8/4 applies: {j}");
        assert!(j.contains("\"staleness_layers\":[{"), "{j}");
        assert!(j.contains("\"tau_max\":5"), "{j}");
        assert!(j.contains("\"hist\":[1,1,0,1,1,0,0,0]"), "{j}");
    }

    #[test]
    fn comm_stats_staleness_and_drop_fractions() {
        let mut c = CommStats::default();
        assert_eq!(c.mean_delivered_staleness(), 0.0);
        assert_eq!(c.drop_frac(), 0.0);
        c.msgs_sent = 10;
        c.msgs_dropped = 2;
        c.msgs_delivered = 4;
        c.staleness_sum = 6;
        assert!((c.mean_delivered_staleness() - 1.5).abs() < 1e-12);
        assert!((c.drop_frac() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn run_stats_fields_keep_legacy_extras_keys() {
        let stats = RunStats {
            achieved_flops_per_s: 1e9,
            queue: QueueStats { pushes: 2, pops: 2, blocked_pushes: 1, depth_sum: 4, max_depth: 3 },
            comm: CommStats {
                msgs_sent: 5,
                bytes_sent: 100,
                msgs_dropped: 1,
                msgs_delivered: 4,
                staleness_sum: 8,
                frames_sent: 0,
                frame_layers: 0,
                links: vec![LinkTraffic {
                    from: 0,
                    to: 1,
                    msgs: 5,
                    bytes: 100,
                    drops: 1,
                    delivered: 4,
                }],
            },
            ..Default::default()
        };
        let summary = RunSummary {
            algorithm: "LayUp".into(),
            curve: Curve::default(),
            mfu: 0.5,
            compute_occupancy: 0.5,
            total_time_s: 1.0,
            total_steps: 10,
            epochs: 1,
            gossip_skipped: 0,
            gossip_applied: 3,
            stats,
        };
        let j = summary.to_json().dump();
        // the typed stats still serialize under the seed-era extras keys
        for key in [
            "achieved_flops_per_s",
            "max_disagreement",
            "final_disagreement",
            "upload_hit_rate",
            "fwd_occupancy",
            "bwd_occupancy",
            "queue_depth_mean",
            "queue_depth_max",
            "queue_blocked_frac",
            "comm_msgs_sent",
            "comm_bytes_sent",
            "comm_dropped",
            "comm_delivered",
            "comm_mean_staleness",
            "stale_applies",
            "stale_tau_mean",
            "stale_tau_max",
            "staleness_layers",
            "recovery_crashes",
            "recovery_joins",
            "checkpoints_saved",
            "membership_epoch",
            "stalled",
            "ps_shards",
            "ps_grad_pushes",
            "ps_param_pulls",
            "ps_repartitions",
            "ps_queue_depth_max",
            "telemetry_spans",
            "telemetry_dropped",
            "links",
        ] {
            assert!(j.contains(&format!("\"{key}\":")), "missing {key} in {j}");
        }
        assert!(j.contains("\"queue_depth_max\":3"));
        assert!(j.contains("\"comm_mean_staleness\":2"), "8 staleness / 4 delivered: {j}");
        assert!(j.contains("\"drops\":1"), "per-link breakdown: {j}");
    }
}
