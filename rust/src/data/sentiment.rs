//! Synthetic sentiment sequences (IMDb analog, Table A3).
//!
//! A vocabulary is split into positive-leaning, negative-leaning and neutral
//! tokens. A sample draws a latent polarity, then emits a token sequence in
//! which polarity-consistent tokens are more likely, with occasional negation
//! markers that *flip* the contribution of the following tokens — so a model
//! has to track at least a little sequential state (which is why the paper
//! used an LSTM and we use a 2-layer RNN).

use super::{stream_rng, Batch, Dataset};
use crate::util::rng::Pcg32;

pub struct SentimentDataset {
    batch: usize,
    seq: usize,
    vocab: usize,
    n_polar: usize,
    negation_token: i32,
    rng: Pcg32,
    eval_seed: u64,
    batches_per_epoch: usize,
    /// training batches drawn (checkpoint cursor)
    drawn: u64,
}

impl SentimentDataset {
    pub fn new(batch: usize, seq: usize, vocab: usize, worker: usize, m: usize, seed: u64) -> Self {
        SentimentDataset {
            batch,
            seq,
            vocab,
            n_polar: vocab / 4,
            negation_token: 0,
            rng: stream_rng(seed, worker, 0x73656e74), // "sent"
            eval_seed: seed ^ 0x7365_6e74,
            batches_per_epoch: (2048 / m.max(1) / batch).max(8),
            drawn: 0,
        }
    }

    /// tokens [1, n_polar] lean positive; (n_polar, 2*n_polar] lean negative;
    /// the rest are neutral; token 0 is the negation marker.
    fn make_batch(&self, rng: &mut Pcg32) -> Batch {
        let mut x = vec![0i32; self.batch * self.seq];
        let mut t = vec![0i32; self.batch];
        for b in 0..self.batch {
            let polarity = rng.below(2) as i32; // 1 = positive
            t[b] = polarity;
            let mut negated = false;
            for s in 0..self.seq {
                let u = rng.next_f32();
                let tok = if u < 0.08 {
                    negated = !negated;
                    self.negation_token
                } else if u < 0.50 {
                    // polarity-consistent token (after accounting for negation)
                    let effective_pos = (polarity == 1) ^ negated;
                    let base = if effective_pos { 1 } else { 1 + self.n_polar };
                    (base + rng.below_usize(self.n_polar)) as i32
                } else {
                    // neutral filler
                    (1 + 2 * self.n_polar
                        + rng.below_usize(self.vocab - 1 - 2 * self.n_polar))
                        as i32
                };
                x[b * self.seq + s] = tok;
            }
        }
        Batch { x_f32: Vec::new(), x_i32: x, targets: t }
    }
}

impl Dataset for SentimentDataset {
    fn next_batch(&mut self) -> Batch {
        self.drawn += 1;
        let mut rng = self.rng.split(0);
        self.make_batch(&mut rng)
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let mut rng = Pcg32::new(self.eval_seed.wrapping_add(i as u64 * 3571));
        self.make_batch(&mut rng)
    }

    fn eval_len(&self) -> usize {
        8
    }

    fn batches_per_epoch(&self) -> usize {
        self.batches_per_epoch
    }

    fn cursor(&self) -> u64 {
        self.drawn
    }

    fn skip(&mut self, n: u64) {
        for _ in 0..n {
            let _ = self.rng.split(0);
        }
        self.drawn += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SentimentDataset {
        SentimentDataset::new(16, 24, 64, 0, 2, 11)
    }

    #[test]
    fn shapes_and_ranges() {
        let mut d = ds();
        let b = d.next_batch();
        assert_eq!(b.x_i32.len(), 16 * 24);
        assert_eq!(b.targets.len(), 16);
        assert!(b.x_i32.iter().all(|&t| (0..64).contains(&t)));
        assert!(b.targets.iter().all(|&t| t == 0 || t == 1));
    }

    #[test]
    fn polar_token_counting_beats_chance() {
        // simple bag-of-words heuristic (ignoring negation) must beat chance
        // but stay below perfect — that gap is what the RNN learns.
        let d = ds();
        let mut rng = Pcg32::new(3);
        let (mut correct, mut total) = (0, 0);
        for _ in 0..50 {
            let b = d.make_batch(&mut rng);
            for s in 0..16 {
                let toks = &b.x_i32[s * 24..(s + 1) * 24];
                let pos = toks.iter().filter(|&&t| (1..=16).contains(&t)).count() as i32;
                let neg = toks
                    .iter()
                    .filter(|&&t| (17..=32).contains(&t))
                    .count() as i32;
                let pred = if pos >= neg { 1 } else { 0 };
                if pred == b.targets[s] {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.6, "bag-of-words acc {acc} too low");
        assert!(acc < 0.999, "task trivial, acc {acc}");
    }

    #[test]
    fn deterministic_eval() {
        let d = ds();
        assert_eq!(d.eval_batch(2).x_i32, d.eval_batch(2).x_i32);
        assert_ne!(d.eval_batch(2).x_i32, d.eval_batch(3).x_i32);
    }
}
