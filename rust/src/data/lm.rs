//! Synthetic language-modeling corpus (MiniPile / WikiText-103 analog).
//!
//! Token streams come from a seeded order-2 Markov chain over the vocabulary
//! with sparse transition structure: from each context, only `branch`
//! successors have non-negligible probability, drawn Zipf-style. This gives
//! the corpus a well-defined entropy floor — an untrained model sits at
//! `log(vocab)` NLL, a converged one approaches the chain's conditional
//! entropy — so perplexity comparisons between training algorithms behave
//! like they do on real text.
//!
//! `CorpusStyle::Finetune` reuses the same machinery with a *different*
//! transition table (disjoint seed): pretraining then finetuning shifts the
//! distribution exactly the way the paper's MiniPile -> WikiText transfer
//! does at our scale.

use super::{stream_rng, Batch, Dataset};
use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusStyle {
    Pretrain,
    Finetune,
}

pub struct LmDataset {
    batch: usize,
    seq: usize,
    vocab: usize,
    branch: usize,
    /// successors[ctx * branch + k] = token
    successors: Vec<u16>,
    /// cumulative probs per context (shared Zipf profile) [branch]
    cum_probs: Vec<f32>,
    rng: Pcg32,
    eval_seed: u64,
    batches_per_epoch: usize,
    /// training batches drawn (checkpoint cursor)
    drawn: u64,
}

impl LmDataset {
    pub fn new(
        batch: usize,
        seq: usize,
        vocab: usize,
        worker: usize,
        m: usize,
        seed: u64,
        style: CorpusStyle,
    ) -> Self {
        let style_tag: u64 = match style {
            CorpusStyle::Pretrain => 0x5052_4554,
            CorpusStyle::Finetune => 0x4649_4e45,
        };
        let mut geo = Pcg32::new(seed ^ style_tag);
        let branch = 8usize.min(vocab);
        // order-1 contexts keep the table small: ctx = previous token
        let mut successors = vec![0u16; vocab * branch];
        for c in 0..vocab {
            // sample `branch` distinct successors
            let mut chosen = Vec::with_capacity(branch);
            while chosen.len() < branch {
                let t = geo.below_usize(vocab) as u16;
                if !chosen.contains(&t) {
                    chosen.push(t);
                }
            }
            successors[c * branch..(c + 1) * branch].copy_from_slice(&chosen);
        }
        // Zipf(1.0) over the branch slots
        let weights: Vec<f32> = (0..branch).map(|k| 1.0 / (k + 1) as f32).collect();
        let total: f32 = weights.iter().sum();
        let mut acc = 0.0;
        let cum_probs = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        LmDataset {
            batch,
            seq,
            vocab,
            branch,
            successors,
            cum_probs,
            rng: stream_rng(seed ^ style_tag, worker, 0x6c6d),
            eval_seed: seed ^ style_tag ^ 0x6576_616c,
            batches_per_epoch: (8192 / m.max(1) / batch).max(8),
            drawn: 0,
        }
    }

    fn next_token(&self, ctx: usize, rng: &mut Pcg32) -> usize {
        let u = rng.next_f32();
        let slot = self
            .cum_probs
            .iter()
            .position(|&c| u <= c)
            .unwrap_or(self.branch - 1);
        self.successors[ctx * self.branch + slot] as usize
    }

    fn make_batch(&self, rng: &mut Pcg32) -> Batch {
        // inputs are tokens[0..seq], targets are tokens[1..seq+1]
        let mut x = vec![0i32; self.batch * self.seq];
        let mut t = vec![0i32; self.batch * self.seq];
        for b in 0..self.batch {
            let mut tok = rng.below_usize(self.vocab);
            for s in 0..self.seq {
                x[b * self.seq + s] = tok as i32;
                tok = self.next_token(tok, rng);
                t[b * self.seq + s] = tok as i32;
            }
        }
        Batch { x_f32: Vec::new(), x_i32: x, targets: t }
    }

    /// Conditional entropy of the chain in nats — the perplexity floor a
    /// perfect model reaches. Exposed for EXPERIMENTS.md sanity checks.
    pub fn entropy_floor_nats(&self) -> f64 {
        let mut probs = Vec::with_capacity(self.branch);
        let mut prev = 0.0f64;
        for &c in &self.cum_probs {
            probs.push(c as f64 - prev);
            prev = c as f64;
        }
        -probs.iter().filter(|&&p| p > 0.0).map(|&p| p * p.ln()).sum::<f64>()
    }
}

impl Dataset for LmDataset {
    fn next_batch(&mut self) -> Batch {
        self.drawn += 1;
        let mut rng = self.rng.split(0);
        self.make_batch(&mut rng)
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let mut rng = Pcg32::new(self.eval_seed.wrapping_add(i as u64 * 6151));
        self.make_batch(&mut rng)
    }

    fn eval_len(&self) -> usize {
        8
    }

    fn batches_per_epoch(&self) -> usize {
        self.batches_per_epoch
    }

    fn cursor(&self) -> u64 {
        self.drawn
    }

    fn skip(&mut self, n: u64) {
        for _ in 0..n {
            let _ = self.rng.split(0);
        }
        self.drawn += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(style: CorpusStyle) -> LmDataset {
        LmDataset::new(4, 16, 64, 0, 4, 9, style)
    }

    #[test]
    fn shapes_and_ranges() {
        let mut d = ds(CorpusStyle::Pretrain);
        let b = d.next_batch();
        assert_eq!(b.x_i32.len(), 4 * 16);
        assert_eq!(b.targets.len(), 4 * 16);
        assert!(b.x_i32.iter().all(|&t| (0..64).contains(&t)));
        assert!(b.targets.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut d = ds(CorpusStyle::Pretrain);
        let b = d.next_batch();
        for bi in 0..4 {
            for s in 0..15 {
                assert_eq!(b.targets[bi * 16 + s], b.x_i32[bi * 16 + s + 1]);
            }
        }
    }

    #[test]
    fn chain_is_sparse_and_predictable() {
        let d = ds(CorpusStyle::Pretrain);
        let floor = d.entropy_floor_nats();
        let uniform = (64f64).ln();
        assert!(floor < uniform * 0.6, "floor {floor} vs uniform {uniform}");
        assert!(floor > 0.5, "chain too deterministic: {floor}");
    }

    #[test]
    fn finetune_distribution_differs() {
        let mut a = ds(CorpusStyle::Pretrain);
        let mut b = ds(CorpusStyle::Finetune);
        assert_ne!(a.successors, b.successors);
        assert_ne!(a.next_batch().x_i32, b.next_batch().x_i32);
    }

    #[test]
    fn eval_deterministic_train_not() {
        let mut d = ds(CorpusStyle::Pretrain);
        let e1 = d.eval_batch(0);
        let e2 = d.eval_batch(0);
        assert_eq!(e1.x_i32, e2.x_i32);
        let t1 = d.next_batch();
        let t2 = d.next_batch();
        assert_ne!(t1.x_i32, t2.x_i32);
    }
}
