//! Synthetic structured vision classification (CIFAR-100 / ImageNet analog).
//!
//! Each of `n_classes` classes is a Gaussian prototype in feature space; a
//! sample is `prototype[class] + within-class "pose" variation + noise`,
//! where the pose variation lives in a low-rank subspace shared across
//! classes (this is what makes the task require more than a linear probe —
//! the pose directions overlap between classes, so the network must learn to
//! project them out). Difficulty is tuned so a linear model plateaus well
//! below an MLP, mirroring the CIFAR gap between shallow and deep nets.

use super::{stream_rng, Batch, Dataset};
use crate::util::rng::Pcg32;

pub struct VisionDataset {
    batch: usize,
    n_in: usize,
    n_classes: usize,
    /// class prototypes [n_classes, n_in]
    prototypes: Vec<f32>,
    /// shared pose basis [n_pose, n_in]
    pose: Vec<f32>,
    n_pose: usize,
    noise: f32,
    pose_scale: f32,
    rng: Pcg32,
    eval_seed: u64,
    batches_per_epoch: usize,
    /// training batches drawn (checkpoint cursor)
    drawn: u64,
}

impl VisionDataset {
    pub fn new(batch: usize, n_in: usize, n_classes: usize, worker: usize, m: usize, seed: u64) -> Self {
        // dataset geometry must be identical across workers -> seeded by
        // (seed, tag) only; the *sample stream* is worker-sharded.
        let mut geo = Pcg32::new(seed ^ 0x5631_5333);
        let n_pose = (n_in / 8).max(2);
        let noise = 0.6f32;
        let pose_scale = 2.0f32;
        // Prototype separation is chosen so the nearest-prototype margin
        // (||Δ|| / 2σ_eff) stays ~1.8 regardless of n_in: the task is far
        // above chance but below saturation, leaving room for a deep net to
        // beat a linear probe (matching the CIFAR regime).
        let sigma_eff =
            (noise * noise + pose_scale * pose_scale * n_pose as f32 / n_in as f32).sqrt();
        let proto_std = 2.0 * 1.8 * sigma_eff / (2.0 * n_in as f32).sqrt();
        let prototypes: Vec<f32> =
            (0..n_classes * n_in).map(|_| geo.normal() * proto_std).collect();
        let pose: Vec<f32> = (0..n_pose * n_in).map(|_| geo.normal() / (n_in as f32).sqrt()).collect();
        VisionDataset {
            batch,
            n_in,
            n_classes,
            prototypes,
            pose,
            n_pose,
            noise,
            pose_scale,
            rng: stream_rng(seed, worker, 0x7261696e), // "rain" (train)
            eval_seed: seed ^ 0x65766121,              // "eva!"
            batches_per_epoch: (4096 / m.max(1) / batch).max(8),
            drawn: 0,
        }
    }

    fn sample_into(&self, rng: &mut Pcg32, x: &mut [f32], y: &mut i32) {
        let c = rng.below_usize(self.n_classes);
        *y = c as i32;
        let proto = &self.prototypes[c * self.n_in..(c + 1) * self.n_in];
        // pose coefficients
        let coefs: Vec<f32> = (0..self.n_pose).map(|_| rng.normal() * self.pose_scale).collect();
        for i in 0..self.n_in {
            let mut pose_term = 0.0;
            for (k, &cf) in coefs.iter().enumerate() {
                pose_term += cf * self.pose[k * self.n_in + i];
            }
            x[i] = proto[i] + pose_term + self.noise * rng.normal();
        }
    }

    fn make_batch(&self, rng: &mut Pcg32) -> Batch {
        let mut x = vec![0.0f32; self.batch * self.n_in];
        let mut t = vec![0i32; self.batch];
        for b in 0..self.batch {
            let mut y = 0i32;
            self.sample_into(rng, &mut x[b * self.n_in..(b + 1) * self.n_in], &mut y);
            t[b] = y;
        }
        Batch { x_f32: x, x_i32: Vec::new(), targets: t }
    }
}

impl Dataset for VisionDataset {
    fn next_batch(&mut self) -> Batch {
        self.drawn += 1;
        let mut rng = self.rng.split(0);
        self.make_batch(&mut rng)
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let mut rng = Pcg32::new(self.eval_seed.wrapping_add(i as u64 * 7919));
        self.make_batch(&mut rng)
    }

    fn eval_len(&self) -> usize {
        8
    }

    fn batches_per_epoch(&self) -> usize {
        self.batches_per_epoch
    }

    fn cursor(&self) -> u64 {
        self.drawn
    }

    fn skip(&mut self, n: u64) {
        // each draw consumes exactly one split() of the stream RNG; advance
        // the stream without materializing the batches
        for _ in 0..n {
            let _ = self.rng.split(0);
        }
        self.drawn += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> VisionDataset {
        VisionDataset::new(32, 64, 10, 0, 4, 42)
    }

    #[test]
    fn shapes_and_target_range() {
        let mut d = ds();
        let b = d.next_batch();
        assert_eq!(b.x_f32.len(), 32 * 64);
        assert_eq!(b.targets.len(), 32);
        assert!(b.x_i32.is_empty());
        assert!(b.targets.iter().all(|&t| (0..10).contains(&t)));
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let d1 = ds();
        let d2 = ds();
        let a = d1.eval_batch(3);
        let b = d2.eval_batch(3);
        assert_eq!(a.x_f32, b.x_f32);
        assert_eq!(a.targets, b.targets);
        let c = d1.eval_batch(4);
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn workers_get_different_shards() {
        let mut d0 = VisionDataset::new(32, 64, 10, 0, 4, 42);
        let mut d1 = VisionDataset::new(32, 64, 10, 1, 4, 42);
        assert_ne!(d0.next_batch().x_f32, d1.next_batch().x_f32);
    }

    #[test]
    fn same_geometry_across_workers() {
        let d0 = VisionDataset::new(32, 64, 10, 0, 4, 42);
        let d1 = VisionDataset::new(32, 64, 10, 1, 4, 42);
        assert_eq!(d0.prototypes, d1.prototypes);
        assert_eq!(d0.pose, d1.pose);
    }

    #[test]
    fn nearest_prototype_is_informative_but_not_perfect() {
        // the task must be learnable (far above chance) yet non-trivial
        let d = ds();
        let mut rng = Pcg32::new(5);
        let (mut correct, mut total) = (0, 0);
        for _ in 0..20 {
            let b = d.make_batch(&mut rng);
            for s in 0..32 {
                let x = &b.x_f32[s * 64..(s + 1) * 64];
                let mut best = (f32::MAX, 0usize);
                for c in 0..10 {
                    let p = &d.prototypes[c * 64..(c + 1) * 64];
                    let dist: f32 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                    if dist < best.0 {
                        best = (dist, c);
                    }
                }
                if best.1 as i32 == b.targets[s] {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.3, "task unlearnable: nearest-prototype acc={acc}");
        assert!(acc < 0.98, "task trivial: nearest-prototype acc={acc}");
    }
}
