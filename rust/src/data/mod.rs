//! Synthetic datasets standing in for CIFAR-100/ImageNet, MiniPile/WikiText
//! and IMDb (DESIGN.md substitution table).
//!
//! Requirements on the substitutes: (a) *learnable* — loss decreases and
//! accuracy/perplexity improve materially with training, so convergence-speed
//! comparisons between algorithms are meaningful; (b) non-trivial — classes
//! overlap / the LM has medium entropy, so models do not saturate instantly;
//! (c) deterministic given a seed, with disjoint train/test streams and
//! per-worker shards (the paper uses sample `S_k` exclusively on device `i`).

pub mod vision;
pub mod lm;
pub mod sentiment;

use anyhow::{bail, Result};

use crate::manifest::ModelManifest;
use crate::util::rng::Pcg32;

/// One training batch in the exact layout the first layer's artifact expects.
#[derive(Clone, Debug)]
pub struct Batch {
    /// f32 features (vision) — empty if the model takes tokens.
    pub x_f32: Vec<f32>,
    /// i32 tokens (lm/sentiment) — empty if the model takes features.
    pub x_i32: Vec<i32>,
    /// i32 targets, flattened to the loss layer's targets_shape.
    pub targets: Vec<i32>,
}

/// A seeded, shardable batch stream.
pub trait Dataset: Send {
    /// Next training batch for this worker's shard.
    fn next_batch(&mut self) -> Batch;
    /// A deterministic held-out batch (index `i` always yields the same data).
    fn eval_batch(&self, i: usize) -> Batch;
    /// Number of eval batches available.
    fn eval_len(&self) -> usize;
    /// Batches per "epoch" per worker (drives epoch-boundary bookkeeping).
    fn batches_per_epoch(&self) -> usize;
    /// Training batches drawn so far — the data-loader cursor a
    /// `resilience::checkpoint` records.
    fn cursor(&self) -> u64;
    /// Fast-forward the train stream as if `n` more batches had been drawn
    /// (checkpoint resume: `skip(cursor)` on a fresh dataset reproduces the
    /// stream position without materializing the skipped batches).
    fn skip(&mut self, n: u64);
}

/// Build the dataset matching a model manifest for worker `worker` of `m`.
/// An unknown `data.kind` in the manifest is a configuration error, not a
/// crash: it propagates as a proper `Err` through the session build.
pub fn build(
    model: &ModelManifest,
    worker: usize,
    m: usize,
    seed: u64,
) -> Result<Box<dyn Dataset>> {
    Ok(match model.data.kind.as_str() {
        "vision" => Box::new(vision::VisionDataset::new(
            model.batch,
            model.data.get("n_in").expect("vision n_in"),
            model.data.get("n_classes").expect("vision n_classes"),
            worker,
            m,
            seed,
        )),
        "lm" => Box::new(lm::LmDataset::new(
            model.batch,
            model.data.get("seq").expect("lm seq"),
            model.data.get("vocab").expect("lm vocab"),
            worker,
            m,
            seed,
            lm::CorpusStyle::Pretrain,
        )),
        "sentiment" => Box::new(sentiment::SentimentDataset::new(
            model.batch,
            model.data.get("seq").expect("sentiment seq"),
            model.data.get("vocab").expect("sentiment vocab"),
            worker,
            m,
            seed,
        )),
        k => bail!("unknown dataset kind {k:?} (expected \"vision\", \"lm\" or \"sentiment\")"),
    })
}

/// Shared helper: deterministic per-(worker, purpose) RNG stream.
pub(crate) fn stream_rng(seed: u64, worker: usize, tag: u64) -> Pcg32 {
    let mut root = Pcg32::new(seed);
    let mut r = root.split(tag);
    r.split(worker as u64 + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Checkpoint-cursor contract, for every dataset kind: a fresh dataset
    /// fast-forwarded with `skip(n)` produces exactly the batches a dataset
    /// that drew `n` batches would produce next.
    #[test]
    fn skip_replays_the_train_stream_exactly() {
        let builders: Vec<Box<dyn Fn() -> Box<dyn Dataset>>> = vec![
            Box::new(|| Box::new(vision::VisionDataset::new(4, 16, 5, 1, 3, 77))),
            Box::new(|| {
                Box::new(lm::LmDataset::new(2, 8, 32, 1, 3, 77, lm::CorpusStyle::Pretrain))
            }),
            Box::new(|| Box::new(sentiment::SentimentDataset::new(4, 8, 32, 1, 3, 77))),
        ];
        for build in builders {
            let mut walked = build();
            for _ in 0..5 {
                let _ = walked.next_batch();
            }
            assert_eq!(walked.cursor(), 5);
            let mut skipped = build();
            skipped.skip(5);
            assert_eq!(skipped.cursor(), 5);
            for _ in 0..3 {
                let a = walked.next_batch();
                let b = skipped.next_batch();
                assert_eq!(a.x_f32, b.x_f32);
                assert_eq!(a.x_i32, b.x_i32);
                assert_eq!(a.targets, b.targets);
            }
        }
    }

    #[test]
    fn stream_rngs_are_decorrelated() {
        let mut a = stream_rng(1, 0, 7);
        let mut b = stream_rng(1, 1, 7);
        let mut c = stream_rng(1, 0, 8);
        let same_ab = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        let same_ac = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same_ab < 4 && same_ac < 4);
    }
}
