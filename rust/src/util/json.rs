//! Minimal JSON parser + writer (the offline crate set has no serde facade).
//!
//! Parses `artifacts/manifest.json` (written by `python/compile/aot.py`) and
//! serializes metric/benchmark reports. Supports the full JSON grammar with
//! the usual Rust niceties (typed accessors, path errors); numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Type { path: String, expected: &'static str },
    Missing(String),
}

// Hand-rolled Display/Error impls: `thiserror` is not in the offline crate
// set (it was never a declared dependency), and these three arms don't earn
// a proc-macro.
impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(at, what) => write!(f, "json parse error at byte {at}: {what}"),
            JsonError::Type { path, expected } => write!(f, "json: expected {expected} at {path}"),
            JsonError::Missing(key) => write!(f, "json: missing key {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Type { path: String::new(), expected: "object" }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Type { path: String::new(), expected: "array" }),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type { path: String::new(), expected: "string" }),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type { path: String::new(), expected: "number" }),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// `get` that tolerates absent keys and JSON null.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self.as_obj().ok()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        }
    }

    pub fn shape_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.i, msg.to_string())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut v = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(self.err("expected :"));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "format": 1,
            "models": {
                "gpt": {
                    "batch": 8,
                    "layers": [
                        {"name": "embed", "shape": [512, 128], "y_shape": null,
                         "flops": 1.5e9, "scale": 0.02, "ok": true}
                    ]
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_usize().unwrap(), 1);
        let gpt = j.get("models").unwrap().get("gpt").unwrap();
        assert_eq!(gpt.get("batch").unwrap().as_usize().unwrap(), 8);
        let layer = &gpt.get("layers").unwrap().as_arr().unwrap()[0];
        assert_eq!(layer.get("name").unwrap().as_str().unwrap(), "embed");
        assert_eq!(layer.get("shape").unwrap().shape_vec().unwrap(), vec![512, 128]);
        assert!(layer.opt("y_shape").is_none());
        assert_eq!(layer.get("flops").unwrap().as_f64().unwrap(), 1.5e9);
    }

    #[test]
    fn roundtrip_dump_parse() {
        let v = obj(vec![
            ("a", num(1.0)),
            ("b", s("hi\n\"there\"")),
            ("c", arr(vec![num(1.5), Json::Bool(false), Json::Null])),
        ]);
        let text = v.dump();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn parses_negative_and_exponent_numbers() {
        let j = Json::parse("[-1.25e-3, 42, -7]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1.25e-3);
        assert_eq!(a[1].as_usize().unwrap(), 42);
        assert_eq!(a[2].as_f64().unwrap(), -7.0);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""café λ""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café λ");
    }
}
