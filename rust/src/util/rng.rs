//! Deterministic, dependency-free RNGs for the training stack.
//!
//! The offline crate set has no `rand`, so we carry our own PCG32 (the
//! workhorse: peer selection, data generation, initialization) seeded via
//! SplitMix64, plus Box–Muller Gaussians for parameter init. Every consumer
//! of randomness in the repo (datasets, init, gossip peer choice, straggler
//! schedules, DES) derives its stream from an explicit seed so runs are
//! reproducible worker-by-worker.

/// SplitMix64 — used to expand a single user seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR variant) — fast, high-quality 32-bit generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from a seed; the stream id is derived from the seed too so two
    /// generators with different seeds are fully decorrelated.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive a child generator (e.g. per-worker stream from a run seed).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The generator's raw `(state, stream)` pair — the checkpoint view.
    /// Restoring via [`Pcg32::from_state`] continues the stream exactly
    /// where it left off.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state`] snapshot.
    pub fn from_state((state, inc): (u64, u64)) -> Pcg32 {
        Pcg32 { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u32) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with the given rate (mean 1/rate). Used by the DES for
    /// jittered compute/communication times.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u: f64 = self.next_f64();
        -(1.0 - u).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniform peer choice: j in [0, m) with j != me.
    pub fn peer(&mut self, me: usize, m: usize) -> usize {
        debug_assert!(m >= 2);
        let j = self.below_usize(m - 1);
        if j >= me {
            j + 1
        } else {
            j
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(1234);
        let mut b = Pcg32::new(1234);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_snapshot_resumes_the_stream_exactly() {
        let mut a = Pcg32::new(77);
        for _ in 0..13 {
            a.next_u32();
        }
        let snap = a.state();
        let tail: Vec<u32> = (0..32).map(|_| a.next_u32()).collect();
        let mut b = Pcg32::from_state(snap);
        let resumed: Vec<u32> = (0..32).map(|_| b.next_u32()).collect();
        assert_eq!(tail, resumed, "restored stream must continue bit-exactly");
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg32::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(42);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn peer_never_self() {
        let mut r = Pcg32::new(5);
        for me in 0..4 {
            for _ in 0..1000 {
                let j = r.peer(me, 4);
                assert_ne!(j, me);
                assert!(j < 4);
            }
        }
    }

    #[test]
    fn peer_is_uniform_over_others() {
        let mut r = Pcg32::new(6);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.peer(1, 4)] += 1;
        }
        assert_eq!(counts[1], 0);
        for &i in &[0usize, 2, 3] {
            assert!((11_000..15_500).contains(&counts[i]), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
