//! Dependency-free utilities: RNGs (no `rand` offline) and JSON (no `serde`).

pub mod json;
pub mod rng;
