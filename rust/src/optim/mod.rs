//! Optimizers and learning-rate schedules (paper Tables A5–A9).
//!
//! Optimizers operate per *layer* on plain gradient slices and write into the
//! shared [`AtomicTensor`] parameter stores — the same lock-free path the
//! updater threads use, so an optimizer step can race with incoming gossip
//! exactly as in the paper (`x^{i,l} ← x̃^{i,l} − η ∇L(S_k, x̂^{i,l})`).

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::shard::{DisjointMut, ShardPool};
use crate::tensor::{AtomicTensor, Tensor};

/// Learning-rate schedule. All schedules support a linear warmup prefix,
/// mirroring the hyper-parameter tables in the paper's appendix.
#[derive(Clone, Debug)]
pub enum Schedule {
    Constant {
        lr: f32,
    },
    /// Cosine decay from `lr` to 0 over `t_max` steps (CIFAR-100, GPT runs).
    Cosine {
        lr: f32,
        t_max: usize,
        warmup_steps: usize,
        warmup_lr: f32,
    },
    /// Linear decay to zero after warmup (ImageNet-1k run).
    Linear {
        lr: f32,
        t_max: usize,
        warmup_steps: usize,
        warmup_lr: f32,
    },
}

impl Schedule {
    pub fn lr_at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Cosine { lr, t_max, warmup_steps, warmup_lr } => {
                if step < warmup_steps {
                    warmup(step, warmup_steps, warmup_lr, lr)
                } else {
                    let t = (step - warmup_steps).min(t_max) as f32 / t_max.max(1) as f32;
                    lr * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
            Schedule::Linear { lr, t_max, warmup_steps, warmup_lr } => {
                if step < warmup_steps {
                    warmup(step, warmup_steps, warmup_lr, lr)
                } else {
                    let t = (step - warmup_steps).min(t_max) as f32 / t_max.max(1) as f32;
                    lr * (1.0 - t)
                }
            }
        }
    }
}

fn warmup(step: usize, warmup_steps: usize, from: f32, to: f32) -> f32 {
    let t = step as f32 / warmup_steps.max(1) as f32;
    from + (to - from) * t
}

/// Optimizer configuration.
#[derive(Clone, Debug)]
pub enum OptimKind {
    /// SGD with (optional) heavy-ball momentum and decoupled weight decay.
    Sgd { momentum: f32, weight_decay: f32 },
    /// AdamW (GPT pretraining/finetuning tables).
    AdamW { beta1: f32, beta2: f32, eps: f32, weight_decay: f32 },
}

impl OptimKind {
    pub fn sgd(momentum: f32, weight_decay: f32) -> Self {
        OptimKind::Sgd { momentum, weight_decay }
    }

    pub fn adamw(weight_decay: f32) -> Self {
        OptimKind::AdamW { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay }
    }
}

/// Checkpoint view of one [`LayerOptimizer`]: momentum / moment buffers and
/// the AdamW bias-correction counter. Scratch buffers are not state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerOptState {
    /// momentum (SGD) or first moment (AdamW), one slice per parameter
    pub m: Vec<Vec<f32>>,
    /// second moment (AdamW; empty for SGD)
    pub v: Vec<Vec<f32>>,
    /// AdamW bias-correction step count
    pub t: u64,
}

/// Checkpoint view of a full per-layer optimizer stack
/// (`crate::algorithms::PerLayerOpt`): one [`LayerOptState`] per layer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptState {
    pub layers: Vec<LayerOptState>,
}

/// Per-layer optimizer state. One `LayerOptimizer` exists per (worker, layer)
/// pair; LayUp's layer-wise granularity means each one can step independently
/// the moment its gradient arrives from the backward pass.
pub struct LayerOptimizer {
    kind: OptimKind,
    /// momentum buffer (SGD) or first moment (AdamW), one slice per param
    m: Vec<Vec<f32>>,
    /// second moment (AdamW only)
    v: Vec<Vec<f32>>,
    /// AdamW bias-correction step count
    t: u64,
    /// reusable scratch (param snapshot / update vector) — §Perf: keeps the
    /// per-layer step allocation-free after the first call. Grown to the
    /// layer's largest param once, never shrunk (no per-param resize churn).
    scratch: Vec<f32>,
    scratch2: Vec<f32>,
    /// shard pool the update traversals run on (§Perf); the serial pool
    /// reproduces the unsharded scalar path bit-for-bit
    pool: Arc<ShardPool>,
}

impl LayerOptimizer {
    pub fn new(kind: OptimKind, param_sizes: &[usize]) -> Self {
        LayerOptimizer::with_pool(kind, param_sizes, ShardPool::serial())
    }

    /// Like [`LayerOptimizer::new`], with the shard pool that
    /// [`LayerOptimizer::step`]/[`LayerOptimizer::step_mix`]/
    /// [`LayerOptimizer::compensate`] split their parameter traversals on.
    pub fn with_pool(kind: OptimKind, param_sizes: &[usize], pool: Arc<ShardPool>) -> Self {
        let m = param_sizes.iter().map(|&n| vec![0.0; n]).collect();
        let v = match kind {
            OptimKind::AdamW { .. } => param_sizes.iter().map(|&n| vec![0.0; n]).collect(),
            _ => Vec::new(),
        };
        LayerOptimizer { kind, m, v, t: 0, scratch: Vec::new(), scratch2: Vec::new(), pool }
    }

    /// Checkpoint view of the optimizer's cross-step state.
    pub fn state_dict(&self) -> LayerOptState {
        LayerOptState { m: self.m.clone(), v: self.v.clone(), t: self.t }
    }

    /// Restore a [`LayerOptimizer::state_dict`] snapshot. The snapshot must
    /// come from an optimizer of the same kind over the same layer shape.
    pub fn load_state_dict(&mut self, state: &LayerOptState) -> Result<()> {
        let sizes_of = |bufs: &[Vec<f32>]| bufs.iter().map(Vec::len).collect::<Vec<_>>();
        if sizes_of(&state.m) != sizes_of(&self.m) || sizes_of(&state.v) != sizes_of(&self.v) {
            bail!(
                "optimizer state_dict shape mismatch (snapshot m/v {:?}/{:?}, live {:?}/{:?})",
                sizes_of(&state.m),
                sizes_of(&state.v),
                sizes_of(&self.m),
                sizes_of(&self.v)
            );
        }
        self.m = state.m.clone();
        self.v = state.v.clone();
        self.t = state.t;
        Ok(())
    }

    /// DC-ASGD delay compensation (Zheng et al., "Asynchronous SGD with
    /// Delay Compensation"): correct a stale gradient with the cheap
    /// Hessian-diagonal approximation
    /// `g ← g + λ · g ⊙ g ⊙ (x_now − x_then)`, where `x_then[i]` holds the
    /// parameter values the gradient was computed against (the forward-time
    /// snapshot) and `x_now` is the store's current value. Mutates `grads`
    /// in place, so it composes with every step flavour (plain and fused).
    /// With `lambda = 0` (or `x_now == x_then`, i.e. τ = 0) this is exact
    /// identity.
    pub fn compensate(
        &mut self,
        params: &[AtomicTensor],
        grads: &mut [Tensor],
        lambda: f32,
        x_then: &[Tensor],
    ) {
        debug_assert_eq!(params.len(), grads.len());
        debug_assert_eq!(params.len(), x_then.len());
        if lambda == 0.0 {
            return;
        }
        // one scratch sized to the layer's largest param up front — §Perf:
        // no `resize` churn inside the per-param loop
        let max_n = params.iter().map(AtomicTensor::numel).max().unwrap_or(0);
        if self.scratch.len() < max_n {
            self.scratch.resize(max_n, 0.0);
        }
        let LayerOptimizer { scratch, pool, .. } = self;
        for ((p, g), xt) in params.iter().zip(grads.iter_mut()).zip(x_then) {
            debug_assert_eq!(g.data.len(), xt.data.len());
            let n = p.numel();
            let x_now = &mut scratch[..n];
            p.load_into_sharded(x_now, pool);
            let xdm = DisjointMut::new(x_now);
            let gdm = DisjointMut::new(&mut g.data);
            pool.run(n, |r| {
                // SAFETY: pool shards are disjoint ranges
                let (x, gd) = unsafe { (xdm.slice(r.clone()), gdm.slice(r.clone())) };
                for ((gv, &xv), &xtv) in gd.iter_mut().zip(x.iter()).zip(&xt.data[r]) {
                    *gv += lambda * *gv * *gv * (xv - xtv);
                }
            });
        }
    }

    /// Apply one update to the shared parameter store for this layer.
    /// `grads[i]` matches `params.tensors[i]` elementwise.
    pub fn step(&mut self, params: &[AtomicTensor], grads: &[Tensor], lr: f32) {
        self.step_with(params, grads, lr, |_, p, lr, u, r| p.sub_scaled_range(r, lr, u));
    }

    /// Fused updater hot path (§Perf): like [`step`], but the final parameter
    /// write also pushes the freshly updated values into `peer` with the
    /// push-sum mixing fractions, in a single traversal per parameter
    /// (`AtomicTensor::sub_scaled_then_mix_into`) instead of the three the
    /// step + load + mix sequence needs. Numerically identical to
    /// `step(params, grads, lr)` followed by mixing the updated values into
    /// `peer`, absent concurrent writers. `peer[i]` matches `params[i]`.
    pub fn step_mix(
        &mut self,
        params: &[AtomicTensor],
        grads: &[Tensor],
        lr: f32,
        peer: &[AtomicTensor],
        keep_frac: f32,
        push_frac: f32,
    ) {
        debug_assert_eq!(params.len(), peer.len());
        self.step_with(params, grads, lr, |pi, p, lr, u, r| {
            p.sub_scaled_then_mix_range(r, lr, u, &peer[pi], keep_frac, push_frac);
        });
    }

    /// Compute each parameter's update vector (momentum / weight decay /
    /// AdamW preconditioning) and hand it to
    /// `write(param_idx, param, lr, u, range)` for the actual store — the
    /// writer decides whether the write is a plain `sub_scaled` or the fused
    /// update+mix traversal.
    ///
    /// §Perf: the whole per-param body (momentum/moment math *and* the
    /// store) runs per shard range on the pool, so the update vector for a
    /// shard is computed and written back while it is still cache-hot.
    /// `write` receives the range-aligned update slice (`u[j]` pairs with
    /// element `range.start + j`) and may be called once per shard. The
    /// arithmetic per element is unchanged, so any pool width is
    /// bit-identical to the serial path.
    fn step_with<W: Fn(usize, &AtomicTensor, f32, &[f32], Range<usize>) + Sync>(
        &mut self,
        params: &[AtomicTensor],
        grads: &[Tensor],
        lr: f32,
        write: W,
    ) {
        debug_assert_eq!(params.len(), grads.len());
        let LayerOptimizer { kind, m, v, t, scratch, scratch2, pool } = self;
        *t += 1;
        match *kind {
            OptimKind::Sgd { momentum, weight_decay } => {
                for (pi, (p, g)) in params.iter().zip(grads).enumerate() {
                    let n = p.numel();
                    if momentum > 0.0 {
                        // v = mu*v + g ; p -= lr * (v + wd*p)
                        if scratch.len() < n {
                            scratch.resize(n, 0.0);
                        }
                        let mdm = DisjointMut::new(&mut m[pi]);
                        let sdm = DisjointMut::new(&mut scratch[..n]);
                        pool.run(n, |r| {
                            // SAFETY: pool shards are disjoint ranges
                            let (buf, sc) =
                                unsafe { (mdm.slice(r.clone()), sdm.slice(r.clone())) };
                            p.load_range(r.clone(), sc);
                            for (k, b) in buf.iter_mut().enumerate() {
                                *b = momentum * *b + g.data[r.start + k];
                                sc[k] = *b + weight_decay * sc[k];
                            }
                            write(pi, p, lr, sc, r);
                        });
                    } else if weight_decay > 0.0 {
                        if scratch.len() < n {
                            scratch.resize(n, 0.0);
                        }
                        let sdm = DisjointMut::new(&mut scratch[..n]);
                        pool.run(n, |r| {
                            // SAFETY: pool shards are disjoint ranges
                            let sc = unsafe { sdm.slice(r.clone()) };
                            p.load_range(r.clone(), sc);
                            for (k, x) in sc.iter_mut().enumerate() {
                                *x = g.data[r.start + k] + weight_decay * *x;
                            }
                            write(pi, p, lr, sc, r);
                        });
                    } else {
                        pool.run(n, |r| write(pi, p, lr, &g.data[r.clone()], r));
                    }
                }
            }
            OptimKind::AdamW { beta1, beta2, eps, weight_decay } => {
                let bc1 = 1.0 - beta1.powi(*t as i32);
                let bc2 = 1.0 - beta2.powi(*t as i32);
                for (pi, (p, g)) in params.iter().zip(grads).enumerate() {
                    let n = p.numel();
                    if scratch.len() < n {
                        scratch.resize(n, 0.0);
                    }
                    if scratch2.len() < n {
                        scratch2.resize(n, 0.0);
                    }
                    let mdm = DisjointMut::new(&mut m[pi]);
                    let vdm = DisjointMut::new(&mut v[pi]);
                    let sdm = DisjointMut::new(&mut scratch[..n]);
                    let s2dm = DisjointMut::new(&mut scratch2[..n]);
                    pool.run(n, |r| {
                        // SAFETY: pool shards are disjoint ranges
                        let (mb, vb) = unsafe { (mdm.slice(r.clone()), vdm.slice(r.clone())) };
                        let (sc, sc2) =
                            unsafe { (sdm.slice(r.clone()), s2dm.slice(r.clone())) };
                        p.load_range(r.clone(), sc);
                        for k in 0..mb.len() {
                            let gk = g.data[r.start + k];
                            mb[k] = beta1 * mb[k] + (1.0 - beta1) * gk;
                            vb[k] = beta2 * vb[k] + (1.0 - beta2) * gk * gk;
                            let mhat = mb[k] / bc1;
                            let vhat = vb[k] / bc2;
                            sc2[k] = mhat / (vhat.sqrt() + eps) + weight_decay * sc[k];
                        }
                        write(pi, p, lr, sc2, r);
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(vals: &[f32]) -> AtomicTensor {
        AtomicTensor::from_tensor(&Tensor::from_vec(&[vals.len()], vals.to_vec()))
    }

    #[test]
    fn sgd_plain_step() {
        let p = store(&[1.0, 2.0]);
        let mut opt = LayerOptimizer::new(OptimKind::sgd(0.0, 0.0), &[2]);
        opt.step(
            std::slice::from_ref(&p),
            &[Tensor::from_vec(&[2], vec![1.0, -1.0])],
            0.5,
        );
        assert_eq!(p.snapshot().data, vec![0.5, 2.5]);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let p = store(&[0.0]);
        let mut opt = LayerOptimizer::new(OptimKind::sgd(0.9, 0.0), &[1]);
        let g = [Tensor::from_vec(&[1], vec![1.0])];
        opt.step(std::slice::from_ref(&p), &g, 1.0); // v=1, p=-1
        opt.step(std::slice::from_ref(&p), &g, 1.0); // v=1.9, p=-2.9
        assert!((p.snapshot().data[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let p = store(&[10.0]);
        let mut opt = LayerOptimizer::new(OptimKind::sgd(0.0, 0.1), &[1]);
        opt.step(std::slice::from_ref(&p), &[Tensor::from_vec(&[1], vec![0.0])], 0.5);
        assert!((p.snapshot().data[0] - 9.5).abs() < 1e-6); // 10 - 0.5*0.1*10
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // minimize (x-3)^2 — AdamW should get close in a few hundred steps
        let p = store(&[0.0]);
        let mut opt = LayerOptimizer::new(OptimKind::adamw(0.0), &[1]);
        for _ in 0..500 {
            let x = p.snapshot().data[0];
            let g = [Tensor::from_vec(&[1], vec![2.0 * (x - 3.0)])];
            opt.step(std::slice::from_ref(&p), &g, 0.05);
        }
        assert!((p.snapshot().data[0] - 3.0).abs() < 0.05);
    }

    #[test]
    fn step_mix_matches_step_then_mix_for_every_optimizer() {
        for kind in [
            OptimKind::sgd(0.0, 0.0),
            OptimKind::sgd(0.9, 0.0),
            OptimKind::sgd(0.9, 5e-4),
            OptimKind::sgd(0.0, 1e-2),
            OptimKind::adamw(0.01),
        ] {
            let init = vec![1.0, -0.5, 2.0, 0.25];
            let peer_init = vec![0.0, 3.0, -1.0, 1.0];
            let g = [Tensor::from_vec(&[4], vec![0.3, -0.7, 0.0, 1.2])];
            let (keep, push) = (0.6f32, 0.4f32);

            // reference: step, then the separate load + mix passes
            let p = store(&init);
            let peer = store(&peer_init);
            let mut opt = LayerOptimizer::new(kind.clone(), &[4]);
            for _ in 0..3 {
                opt.step(std::slice::from_ref(&p), &g, 0.1);
                let snap = p.snapshot();
                peer.mix_from(keep, push, &snap.data);
            }

            // fused path
            let pf = store(&init);
            let peerf = store(&peer_init);
            let mut optf = LayerOptimizer::new(kind.clone(), &[4]);
            for _ in 0..3 {
                optf.step_mix(
                    std::slice::from_ref(&pf),
                    &g,
                    0.1,
                    std::slice::from_ref(&peerf),
                    keep,
                    push,
                );
            }

            assert_eq!(pf.snapshot().data, p.snapshot().data, "{kind:?} params");
            assert_eq!(peerf.snapshot().data, peer.snapshot().data, "{kind:?} peer");
        }
    }

    /// Checkpoint contract: snapshotting mid-momentum and restoring into a
    /// fresh optimizer continues bit-identically to the uninterrupted run,
    /// for both optimizer families.
    #[test]
    fn state_dict_roundtrip_resumes_bit_identically() {
        for kind in [OptimKind::sgd(0.9, 5e-4), OptimKind::adamw(0.01)] {
            let g = [Tensor::from_vec(&[3], vec![0.5, -1.0, 2.0])];
            let run = |resume_at: Option<usize>| -> Vec<f32> {
                let p = store(&[1.0, -2.0, 0.5]);
                let mut opt = LayerOptimizer::new(kind.clone(), &[3]);
                for step in 0..8 {
                    if resume_at == Some(step) {
                        let snap = opt.state_dict();
                        opt = LayerOptimizer::new(kind.clone(), &[3]);
                        opt.load_state_dict(&snap).unwrap();
                    }
                    opt.step(std::slice::from_ref(&p), &g, 0.05);
                    let _ = step;
                }
                p.snapshot().data
            };
            assert_eq!(run(None), run(Some(4)), "{kind:?}");
        }
        // shape mismatches are rejected, not silently truncated
        let mut opt = LayerOptimizer::new(OptimKind::sgd(0.9, 0.0), &[3]);
        let bad = LayerOptState { m: vec![vec![0.0; 2]], v: Vec::new(), t: 1 };
        assert!(opt.load_state_dict(&bad).is_err());
    }

    /// DC compensation contract: identity when nothing moved (τ = 0) or
    /// λ = 0, and exactly `g + λ·g⊙g⊙(x_now − x_then)` otherwise.
    #[test]
    fn dc_compensation_matches_formula_and_is_identity_at_zero() {
        let p = store(&[2.0, -1.0, 0.5]);
        let mut opt = LayerOptimizer::new(OptimKind::sgd(0.0, 0.0), &[3]);

        // x_now == x_then: no correction, whatever lambda
        let mut g = [Tensor::from_vec(&[3], vec![1.0, -2.0, 0.25])];
        let unchanged = g[0].data.clone();
        opt.compensate(std::slice::from_ref(&p), &mut g, 0.1, &[p.snapshot()]);
        assert_eq!(g[0].data, unchanged);

        // lambda == 0: identity even when the params moved
        let x_then = [Tensor::from_vec(&[3], vec![0.0, 0.0, 0.0])];
        opt.compensate(std::slice::from_ref(&p), &mut g, 0.0, &x_then);
        assert_eq!(g[0].data, unchanged);

        // moved params + positive lambda: the DC-ASGD formula elementwise
        let lambda = 0.04f32;
        opt.compensate(std::slice::from_ref(&p), &mut g, lambda, &x_then);
        let x_now = p.snapshot().data;
        for k in 0..3 {
            let want = unchanged[k] + lambda * unchanged[k] * unchanged[k] * (x_now[k] - 0.0);
            assert!((g[0].data[k] - want).abs() < 1e-6, "k={k}");
        }
    }

    /// The pooled optimizer paths must be **bit-identical** to the serial
    /// ones for every optimizer family — plain step, fused step_mix, and DC
    /// compensation — at a prime size above threads·chunk so the last shard
    /// is ragged.
    #[test]
    fn pooled_optimizer_matches_serial_bit_for_bit() {
        let n = 5003;
        let mk = |seed: u32| -> Vec<f32> {
            let mut s = seed;
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(1664525).wrapping_add(1013904223);
                    (s >> 8) as f32 / (1 << 24) as f32 - 0.5
                })
                .collect()
        };
        let init = mk(1);
        let peer_init = mk(2);
        let g = Tensor::from_vec(&[n], mk(3));
        for kind in [
            OptimKind::sgd(0.0, 0.0),
            OptimKind::sgd(0.9, 0.0),
            OptimKind::sgd(0.9, 5e-4),
            OptimKind::sgd(0.0, 1e-2),
            OptimKind::adamw(0.01),
        ] {
            let run = |pool: Arc<ShardPool>| {
                let p = store(&init);
                let peer = store(&peer_init);
                let mut opt = LayerOptimizer::with_pool(kind.clone(), &[n], pool);
                let mut gc = [g.clone()];
                opt.compensate(
                    std::slice::from_ref(&p),
                    &mut gc,
                    0.04,
                    &[Tensor::zeros(&[n])],
                );
                for _ in 0..2 {
                    opt.step_mix(
                        std::slice::from_ref(&p),
                        &gc,
                        0.1,
                        std::slice::from_ref(&peer),
                        0.6,
                        0.4,
                    );
                }
                opt.step(std::slice::from_ref(&p), &gc, 0.05);
                let bits =
                    |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
                (
                    bits(&p.state_dict()),
                    bits(&peer.state_dict()),
                    bits(&gc[0].data),
                )
            };
            assert_eq!(run(ShardPool::serial()), run(ShardPool::new(4)), "{kind:?}");
        }
    }

    #[test]
    fn cosine_schedule_shape() {
        let s = Schedule::Cosine { lr: 1.0, t_max: 100, warmup_steps: 10, warmup_lr: 0.1 };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!(s.lr_at(5) > 0.1 && s.lr_at(5) < 1.0);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(60) - 0.5).abs() < 0.01);
        assert!(s.lr_at(110) < 1e-6);
    }

    #[test]
    fn linear_schedule_shape() {
        let s = Schedule::Linear { lr: 0.3, t_max: 90, warmup_steps: 2, warmup_lr: 0.1 };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(2) - 0.3).abs() < 1e-6);
        assert!((s.lr_at(47) - 0.15).abs() < 0.01);
        assert!(s.lr_at(92) < 1e-6);
    }
}
