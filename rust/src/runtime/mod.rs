//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client (adapting /opt/xla-example/load_hlo).
//!
//! Thread model: the `xla` crate's wrappers are `Rc`-based and thus
//! `!Send`/`!Sync`, so **each compute thread owns its own [`Runtime`]** —
//! its own `PjRtClient` and its own compiled executables. In the serial loop
//! that is one runtime per worker; in decoupled mode every forward-pool and
//! backward-pool thread gets its own, and passes cross threads only as
//! host-side buffers (`model::HostPass`). That matches the
//! paper's deployment (one process context per device) and keeps the gossip
//! path (which only touches [`crate::tensor::AtomicTensor`]s) free of any
//! XLA state. Compilation cost stays bounded because layers with equal
//! `share_key` share one artifact: a runtime compiles each *distinct* HLO
//! file exactly once (per-path cache).
//!
//! Hot-path performance (DESIGN.md §Perf): parameter uploads are cached by
//! the layer's version counter (see [`crate::model`]), so a parameter tensor
//! is converted to a `Literal` again only after a gossip write or optimizer
//! step actually changed it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

/// One compiled artifact (fwd or bwd of one layer shape).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// path it was loaded from (diagnostics)
    pub path: PathBuf,
    /// cumulative execution stats
    pub calls: RefCell<u64>,
    pub exec_seconds: RefCell<f64>,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    /// (aot.py lowers everything with `return_tuple=True`.)
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.path.display()))?;
        let outs = lit.to_tuple().context("decomposing output tuple")?;
        *self.calls.borrow_mut() += 1;
        *self.exec_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        Ok(outs)
    }
}

/// Thread-local runtime: PJRT CPU client + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, Rc<Executable>>,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by absolute path).
    pub fn load(&mut self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.get(path) {
            return Ok(Rc::clone(e));
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::debug!("compiled {} in {:?}", path.display(), t0.elapsed());
        let e = Rc::new(Executable {
            exe,
            path: path.to_path_buf(),
            calls: RefCell::new(0),
            exec_seconds: RefCell::new(0.0),
        });
        self.cache.insert(path.to_path_buf(), Rc::clone(&e));
        Ok(e)
    }

    /// Number of distinct compiled artifacts.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
///
/// §Perf: `create_from_shape_and_untyped_data` performs ONE host copy;
/// the original `vec1(..).reshape(..)` path copied twice (vec1 into a 1-D
/// literal, reshape into a fresh literal) — see EXPERIMENTS.md §Perf.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Read an f32 literal back into a Vec.
pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read an f32 literal into a reusable host buffer (§Perf: the decoupled
/// pass queue downloads every activation once per step — steady-state this
/// costs one memcpy and zero allocations, because `resize` is a no-op once
/// the pooled buffer reached the activation's size).
pub fn literal_read_f32_into(lit: &xla::Literal, out: &mut Vec<f32>) -> Result<()> {
    let n = lit.element_count();
    out.resize(n, 0.0);
    lit.copy_raw_to::<f32>(out.as_mut_slice())
        .context("copying literal into host buffer")?;
    Ok(())
}

/// Read a scalar f32 (e.g. loss) from a literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
