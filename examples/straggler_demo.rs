//! Straggler robustness demo (Fig 3 in miniature): inject an artificial
//! delay into one worker and watch DDP slow down while LayUp shrugs.
//!
//!     cargo run --release --example straggler_demo

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::session::events::TrainEvent;
use layup::session::SessionBuilder;

fn main() -> Result<()> {
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    let steps = 60;
    println!("mlpnet18, 3 workers, {steps} steps; worker 1 delayed by k iterations of idle\n");
    println!("{:<10} {:>8} {:>12} {:>12} {:>8}", "method", "delay", "accuracy", "time (s)", "idles");
    for algo in [Algorithm::Ddp, Algorithm::LayUp] {
        for delay in [0.0, 4.0] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 3, steps);
            cfg.eval_every = steps / 6;
            cfg.straggler = if delay > 0.0 { Some((1, delay)) } else { None };
            // count the injected idle periods through the typed event stream
            let idles = Arc::new(AtomicUsize::new(0));
            let counter = {
                let idles = Arc::clone(&idles);
                move |ev: &TrainEvent| {
                    if matches!(ev, TrainEvent::StragglerInjected { .. }) {
                        idles.fetch_add(1, Ordering::Relaxed);
                    }
                }
            };
            let r = SessionBuilder::new(cfg)
                .observer(Arc::new(counter))
                .build(&manifest)?
                .run()?;
            println!(
                "{:<10} {:>8.0} {:>11.1}% {:>12.1} {:>8}",
                r.algorithm,
                delay,
                100.0 * r.curve.best_accuracy(),
                r.total_time_s,
                idles.load(Ordering::Relaxed)
            );
        }
    }
    println!("\nDDP's barrier forces every worker to wait for the straggler each step;");
    println!("LayUp's updater threads keep gossiping so the cluster never stalls.");
    Ok(())
}
