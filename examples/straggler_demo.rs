//! Straggler robustness demo (Fig 3 in miniature): inject an artificial
//! delay into one worker and watch DDP slow down while LayUp shrugs.
//!
//!     cargo run --release --example straggler_demo

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::coordinator;
use layup::manifest::Manifest;

fn main() -> Result<()> {
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    let steps = 60;
    println!("mlpnet18, 3 workers, {steps} steps; worker 1 delayed by k iterations of idle\n");
    println!("{:<10} {:>8} {:>12} {:>12}", "method", "delay", "accuracy", "time (s)");
    for algo in [Algorithm::Ddp, Algorithm::LayUp] {
        for delay in [0.0, 4.0] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 3, steps);
            cfg.eval_every = steps / 6;
            cfg.straggler = if delay > 0.0 { Some((1, delay)) } else { None };
            let r = coordinator::run(&cfg, &manifest)?;
            println!(
                "{:<10} {:>8.0} {:>11.1}% {:>12.1}",
                r.algorithm,
                delay,
                100.0 * r.curve.best_accuracy(),
                r.total_time_s
            );
        }
    }
    println!("\nDDP's barrier forces every worker to wait for the straggler each step;");
    println!("LayUp's updater threads keep gossiping so the cluster never stalls.");
    Ok(())
}
