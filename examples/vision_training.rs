//! Vision workload (CIFAR-analog): train the MLPNet-18 residual network with
//! every algorithm of the paper on the same data and compare convergence —
//! a miniature Table 1/2, driven through the Session API.
//!
//!     cargo run --release --example vision_training

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::optim::{OptimKind, Schedule};
use layup::session::SessionBuilder;

fn main() -> Result<()> {
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    let steps: usize = std::env::var("LAYUP_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    let workers = 3;

    println!("mlpnet18 on synthetic-100, {workers} workers, {steps} steps\n");
    println!("{:<14} {:>10} {:>10} {:>12}", "method", "best acc", "TTC (s)", "occupancy");
    for &algo in Algorithm::all_paper() {
        let mut cfg = TrainConfig::new("mlpnet18", algo, workers, steps);
        cfg.optim = OptimKind::sgd(0.9, 5e-4);
        cfg.schedule = Schedule::Cosine { lr: 0.04, t_max: steps, warmup_steps: 0, warmup_lr: 0.0 };
        cfg.eval_every = (steps / 12).max(1);
        let r = SessionBuilder::new(cfg).build(&manifest)?.run()?;
        println!(
            "{:<14} {:>9.1}% {:>10.1} {:>11.1}%",
            r.algorithm,
            100.0 * r.curve.best_accuracy(),
            r.curve.time_to_convergence(0.01).unwrap_or(r.total_time_s),
            100.0 * r.compute_occupancy
        );
    }
    Ok(())
}
