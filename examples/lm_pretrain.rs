//! End-to-end driver (EXPERIMENTS.md §E2E): pretrain the GPT-mini causal
//! transformer with LayUp on the synthetic Markov corpus for a few hundred
//! steps, logging the loss curve — proof that all three layers compose:
//! Pallas kernels (L1) inside the JAX per-layer artifacts (L2), executed and
//! coordinated lock-free by the Rust cluster (L3). The run also streams its
//! typed event log to results/e2e_lm_pretrain_events.jsonl (EXPERIMENTS.md
//! §Events).
//!
//!     cargo run --release --example lm_pretrain
//!
//! Env: LAYUP_STEPS (default 300), LAYUP_WORKERS (default 4).

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::session::SessionBuilder;

fn main() -> Result<()> {
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    let steps: usize = std::env::var("LAYUP_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let workers: usize = std::env::var("LAYUP_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);

    let model = manifest.model("gpt_mini")?;
    println!(
        "pretraining gpt_mini ({} params, {} layers) with LayUp on {} workers for {} steps",
        model.param_count,
        model.layers.len(),
        workers,
        steps
    );

    let mut cfg = TrainConfig::new("gpt_mini", Algorithm::LayUp, workers, steps);
    cfg.optim = layup::optim::OptimKind::adamw(0.01);
    cfg.schedule = layup::optim::Schedule::Cosine {
        lr: 3e-3,
        t_max: steps,
        warmup_steps: steps / 10,
        warmup_lr: 5e-4,
    };
    cfg.eval_every = (steps / 20).max(1);
    cfg.track_drift_every = (steps / 10).max(1);

    let out = layup::artifacts_dir().parent().unwrap().join("results");
    std::fs::create_dir_all(&out)?;
    let summary = SessionBuilder::new(cfg)
        .jsonl_sink(out.join("e2e_lm_pretrain_events.jsonl"))?
        .build(&manifest)?
        .run()?;

    println!("\n{:<8} {:>9} {:>10} {:>12} {:>10}", "step", "time(s)", "loss", "perplexity", "tok acc");
    for p in &summary.curve.points {
        println!(
            "{:<8} {:>9.1} {:>10.4} {:>12.2} {:>9.1}%",
            p.step,
            p.time_s,
            p.loss,
            p.perplexity(),
            100.0 * p.accuracy
        );
    }
    println!(
        "\nfinal perplexity {:.2} (corpus floor ≈ e^H of the Markov chain)  drift max {:.4} final {:.4}",
        summary.curve.best_loss().exp(),
        summary.stats.max_disagreement,
        summary.stats.final_disagreement,
    );
    // persist the loss curve for EXPERIMENTS.md
    std::fs::write(out.join("e2e_lm_pretrain.csv"), summary.curve.to_csv())?;
    println!("loss curve -> results/e2e_lm_pretrain.csv");
    println!("typed event log -> results/e2e_lm_pretrain_events.jsonl");
    Ok(())
}
