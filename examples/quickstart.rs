//! Quickstart: train one model with LayUp on a 2-worker thread cluster and
//! print the learning curve — the 30-second tour of the public Session API.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::session::events::TrainEvent;
use layup::session::SessionBuilder;

fn main() -> Result<()> {
    // 1. load the AOT artifact manifest produced by `make artifacts`
    let manifest = Manifest::load(&layup::artifacts_dir())?;

    // 2. describe the run: model, algorithm, cluster size, steps
    let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 60);
    cfg.eval_every = 10;

    // 3. build a session and run — worker threads execute the per-layer XLA
    //    artifacts; LayUp's updater threads gossip layer-wise updates
    //    concurrently. Observers receive the typed event stream live; any
    //    `Fn(&TrainEvent)` closure works.
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(|ev: &TrainEvent| {
            if let TrainEvent::EvalPoint { step, loss, .. } = ev {
                eprintln!("  [live] step {step}: loss {loss:.4}");
            }
        }))
        .build(&manifest)?
        .run()?;

    // 4. inspect the results
    println!("algorithm: {}", summary.algorithm);
    println!("{:<8} {:>8} {:>10} {:>10}", "step", "time(s)", "loss", "accuracy");
    for p in &summary.curve.points {
        println!("{:<8} {:>8.2} {:>10.4} {:>9.1}%", p.step, p.time_s, p.loss, 100.0 * p.accuracy);
    }
    println!(
        "\nbest accuracy {:.1}%   gossip pushes applied {}, skipped-on-contention {}",
        100.0 * summary.curve.best_accuracy(),
        summary.gossip_applied,
        summary.gossip_skipped
    );
    Ok(())
}
