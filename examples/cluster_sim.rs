//! Paper-scale what-if: use the discrete-event simulator to project every
//! algorithm's wall-clock and MFU on the paper's three hardware configs
//! without owning a single GPU.
//!
//!     cargo run --release --example cluster_sim

use layup::sim::{simulate, Cluster, SimAlgo, Workload};

fn main() {
    let scenarios = [
        ("CIFAR-100 / ResNet-50", Cluster::c1(), Workload::resnet50_cifar(3), 12),
        ("ImageNet-1k / ResNet-50", Cluster::c1(), Workload::resnet50_imagenet(3), 48),
        ("MiniPile / GPT-2 Medium", Cluster::c2(), Workload::gpt2_medium(8), 20),
        ("WikiText-103 / GPT-2 XL", Cluster::c3(), Workload::gpt2_xl(4), 48),
    ];
    for (label, cluster, w, period) in scenarios {
        println!("\n=== {label} on {} ({} devices) ===", cluster.name, cluster.m);
        println!(
            "{:<10} {:>12} {:>9} {:>8} {:>12}",
            "method", "wall (s)", "occup.", "MFU", "comm (GB)"
        );
        for algo in SimAlgo::paper_set(period) {
            let r = simulate(&cluster, &w, algo, 1);
            println!(
                "{:<10} {:>12.0} {:>8.1}% {:>7.1}% {:>12.0}",
                r.algo,
                r.wall_s,
                100.0 * r.occupancy,
                100.0 * r.mfu,
                r.comm_gbytes
            );
        }
    }
    println!("\nand the straggler sweep (Fig 3B shape), ResNet-18/CIFAR @C1:");
    println!("{:<10} {:>8} {:>12}", "method", "delay", "wall (s)");
    for algo in [SimAlgo::Ddp, SimAlgo::Co2 { period: 12 }, SimAlgo::AdPsgd, SimAlgo::GoSgd, SimAlgo::LayUp] {
        for d in [0.0, 8.0, 32.0] {
            let c = Cluster::c1().with_straggler(0, d);
            let w = Workload::resnet18_cifar(c.m);
            let r = simulate(&c, &w, algo, 1);
            println!("{:<10} {:>8.0} {:>12.0}", r.algo, d, r.wall_s);
        }
    }
}
