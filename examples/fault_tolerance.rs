//! Fault tolerance tour: crash a worker mid-run (chaos injection) and watch
//! it rejoin, then write periodic checkpoints and resume a fresh session
//! from the snapshot — the resilience subsystem end-to-end.
//!
//! (Restart faults and periodic checkpoints are deliberately separate runs:
//! a rejoined worker trails the survivors, so combining them is rejected by
//! `TrainConfig::validate` — see the resilience module docs.)
//!
//!     make artifacts && cargo run --release --example fault_tolerance

use std::sync::Arc;

use anyhow::Result;
use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::resilience::{checkpoint, FaultPlan};
use layup::session::events::TrainEvent;
use layup::session::SessionBuilder;

fn main() -> Result<()> {
    let manifest = Manifest::load(&layup::artifacts_dir())?;

    // 1. chaos injection: worker 1 dies at step 20 and is respawned 0.5s
    //    later — it re-enters gossip from a live peer's parameters, with
    //    push-sum weight mass conserved throughout.
    let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 60);
    cfg.eval_every = 10;
    cfg.faults = FaultPlan::default().crash_restart(1, 20, 0.5);
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(|ev: &TrainEvent| match ev {
            TrainEvent::WorkerCrashed { worker, step } => {
                eprintln!("  [chaos] worker {worker} crashed at step {step}");
            }
            TrainEvent::WorkerJoined { worker, step, epoch } => {
                eprintln!("  [chaos] worker {worker} rejoined at step {step} (epoch {epoch})");
            }
            _ => {}
        }))
        .build(&manifest)?
        .run()?;
    let rec = &summary.stats.recovery;
    println!(
        "chaos run: {} steps, best loss {:.4}, {} crash(es), {} rejoin(s)",
        summary.total_steps,
        summary.curve.best_loss(),
        rec.crashes,
        rec.joins
    );

    // 2. periodic checkpoints: quiesce at every 15-step boundary and
    //    snapshot the full training state into step-XXXXXX directories.
    let ckpt_dir = std::env::temp_dir().join("layup-fault-tolerance-demo");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 60);
    cfg.eval_every = 10;
    let summary = SessionBuilder::new(cfg)
        .checkpoint_every(15)
        .checkpoint_dir(ckpt_dir.clone())
        .observer(Arc::new(|ev: &TrainEvent| {
            if let TrainEvent::CheckpointSaved { step, path } = ev {
                eprintln!("  [ckpt] step {step} -> {path}");
            }
        }))
        .build(&manifest)?
        .run()?;
    println!(
        "checkpointed run: {} steps, best loss {:.4}, {} checkpoint(s)",
        summary.total_steps,
        summary.curve.best_loss(),
        summary.stats.recovery.checkpoints_saved
    );

    // 3. resume a fresh session from the latest snapshot and train on — the
    //    curve continues where the checkpoint left it.
    let latest = checkpoint::resolve(&ckpt_dir)?;
    println!("resuming from {}", latest.display());
    let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 60);
    cfg.eval_every = 10;
    let resumed = SessionBuilder::new(cfg)
        .build(&manifest)?
        .resume_from(&latest)?
        .run()?;
    println!(
        "resumed run: {} total curve points, best loss {:.4}",
        resumed.curve.points.len(),
        resumed.curve.best_loss()
    );
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    Ok(())
}
