"""L1 Pallas kernels for the LayUp reproduction.

Every kernel runs under `interpret=True` (CPU PJRT), is tiled for the TPU
memory hierarchy (see DESIGN.md §Hardware-Adaptation), and carries a custom
VJP whose backward is itself Pallas. `ref.py` is the pure-jnp oracle used by
the pytest/hypothesis suite.
"""

from .matmul import matmul, linear, matmul_fwd_pallas, actgrad_pallas
from .layernorm import layernorm, layernorm_nd, layernorm_fwd_pallas, layernorm_bwd_pallas
from .softmax_xent import softmax_xent, softmax_xent_fwd_pallas, softmax_xent_bwd_pallas
from .attention import attention, attention_fwd_pallas, attention_bwd_pallas

__all__ = [
    "matmul",
    "linear",
    "matmul_fwd_pallas",
    "actgrad_pallas",
    "layernorm",
    "layernorm_nd",
    "layernorm_fwd_pallas",
    "layernorm_bwd_pallas",
    "softmax_xent",
    "softmax_xent_fwd_pallas",
    "softmax_xent_bwd_pallas",
    "attention",
    "attention_fwd_pallas",
    "attention_bwd_pallas",
]
