"""Causal scaled-dot-product attention Pallas kernels (fwd + bwd).

GPU→TPU rethink (DESIGN.md §Hardware-Adaptation): instead of a
threadblock-per-query-tile flash decomposition with shared-memory softmax
state, the kernel processes one head per grid step with the full (S, S)
score tile resident in VMEM — at the sequence lengths this repo trains
(S ≤ 256), S² f32 scores fit VMEM many times over, so the online-softmax
machinery would only add passes. Both matmuls in the kernel hit the MXU;
the mask/softmax run on the VPU between them, fused so scores never leave
VMEM.

The backward kernel implements the standard attention VJP per head
(recompute-style: p is rebuilt from q, k rather than stashed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, scale: float):
    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(row >= col, scores, -1e9)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention_fwd_pallas(q, k, v, causal: bool = True):
    """q, k, v: [H, S, Dh] (batch and heads folded together). Returns [H, S, Dh]."""
    h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_attn_fwd_kernel, causal=causal, scale=scale),
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def _attn_bwd_kernel(
    q_ref, k_ref, v_ref, gy_ref, gq_ref, gk_ref, gv_ref, *, causal: bool, scale: float
):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    gy = gy_ref[0]
    s = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(row >= col, scores, -1e9)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    gv_ref[0] = jnp.dot(p.T, gy, preferred_element_type=jnp.float32)
    gp = jnp.dot(gy, v.T, preferred_element_type=jnp.float32)
    gs = p * (gp - jnp.sum(gp * p, axis=-1, keepdims=True))
    gq_ref[0] = jnp.dot(gs, k, preferred_element_type=jnp.float32) * scale
    gk_ref[0] = jnp.dot(gs.T, q, preferred_element_type=jnp.float32) * scale


def attention_bwd_pallas(q, k, v, gy, causal: bool = True):
    h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    shape = jax.ShapeDtypeStruct((h, s, dh), q.dtype)
    return pl.pallas_call(
        functools.partial(_attn_bwd_kernel, causal=causal, scale=scale),
        grid=(h,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[shape, shape, shape],
        interpret=True,
    )(q, k, v, gy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Differentiable causal attention over folded heads: [H, S, Dh]."""
    return attention_fwd_pallas(q, k, v, causal)


def _attn_vjp_fwd(q, k, v, causal):
    return attention_fwd_pallas(q, k, v, causal), (q, k, v)


def _attn_vjp_bwd(causal, res, gy):
    q, k, v = res
    return attention_bwd_pallas(q, k, v, gy, causal)


attention.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)
