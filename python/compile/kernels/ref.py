"""Pure-jnp reference oracle for every Pallas kernel in this package.

These are the ground-truth implementations used by pytest (and hypothesis
sweeps) to validate the Pallas kernels in matmul.py / layernorm.py /
softmax_xent.py / attention.py. They are deliberately written in the most
direct jnp style possible — no tiling, no tricks — so that a mismatch
always points at the kernel, not the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def act_apply(z, act: str):
    """Reference activation. `act` in {'none', 'relu', 'gelu'}."""
    if act == "none":
        return z
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "gelu":
        # tanh-approximation GELU (what the kernel implements, matching GPT-2)
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        return 0.5 * z * (1.0 + jnp.tanh(c * (z + 0.044715 * z**3)))
    raise ValueError(f"unknown act {act!r}")


def act_grad(z, act: str):
    """d act(z) / d z, reference."""
    if act == "none":
        return jnp.ones_like(z)
    if act == "relu":
        return (z > 0.0).astype(z.dtype)
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        inner = c * (z + 0.044715 * z**3)
        t = jnp.tanh(inner)
        dinner = c * (1.0 + 3 * 0.044715 * z**2)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * dinner
    raise ValueError(f"unknown act {act!r}")


def matmul(x, w, b=None, act: str = "none"):
    """y = act(x @ w + b). x: [M, K], w: [K, N], b: [N] or None."""
    z = x @ w
    if b is not None:
        z = z + b
    return act_apply(z, act)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layernorm over the last axis. x: [M, D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + eps)
    return xhat * gamma + beta


def layernorm_bwd(x, gamma, gy, eps: float = 1e-5):
    """Analytic layernorm backward. Returns (gx, ggamma, gbeta)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + eps)
    xhat = (x - mu) * rstd
    ggamma = jnp.sum(gy * xhat, axis=0)
    gbeta = jnp.sum(gy, axis=0)
    gxhat = gy * gamma
    gx = rstd * (
        gxhat
        - jnp.mean(gxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True)
    )
    return gx, ggamma, gbeta


def softmax_xent(logits, targets, n_valid: int):
    """Mean cross-entropy over rows, with classes >= n_valid masked out.

    logits: [M, C] f32, targets: [M] i32 (< n_valid). Returns scalar mean
    NLL and the count of argmax-correct rows (restricted to valid classes).
    """
    m, c = logits.shape
    mask = jnp.arange(c) < n_valid
    masked = jnp.where(mask, logits, -1e9)
    mx = masked.max(-1)
    lse = jnp.log(jnp.sum(jnp.exp(masked - mx[:, None]), -1)) + mx
    nll = lse - masked[jnp.arange(m), targets]
    correct = jnp.sum((jnp.argmax(masked, axis=-1) == targets).astype(jnp.float32))
    return jnp.mean(nll), correct


def softmax_xent_bwd(logits, targets, n_valid: int, gloss=1.0):
    """d mean-NLL / d logits."""
    m, c = logits.shape
    mask = jnp.arange(c) < n_valid
    masked = jnp.where(mask, logits, -1e9)
    p = jnp.exp(masked - masked.max(-1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    onehot = jnp.zeros_like(p).at[jnp.arange(m), targets].set(1.0)
    return (p - onehot) * (gloss / m) * mask.astype(logits.dtype)


def attention(q, k, v, causal: bool = True):
    """Scaled dot-product attention. q,k,v: [H, S, Dh] (heads folded out front)."""
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        causal_mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(causal_mask, scores, -1e9)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
