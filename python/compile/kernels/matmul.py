"""Fused matmul + bias + activation Pallas kernel (L1 hot path).

TPU mapping of the paper's cuBLAS/LibTorch linear layers: the kernel tiles
`x[M,K] @ w[K,N]` into (bm, bk) x (bk, bn) VMEM blocks fed to the MXU, with
the bias add and activation fused into the epilogue of the last K step so the
pre-activation never round-trips through HBM. On this image the kernel runs
under `interpret=True` (CPU PJRT cannot execute Mosaic custom-calls); block
shapes are still chosen for the TPU VMEM budget — see DESIGN.md §Perf.

Autodiff: `matmul` carries a custom VJP whose backward is itself built from
the same Pallas kernel (gx = gz @ wᵀ, gw = xᵀ @ gz), with the activation
derivative computed by a row-tiled elementwise Pallas kernel. The backward
recomputes the pre-activation z from (x, w, b) — recompute-style backprop,
matching the per-layer artifact interface used by the Rust coordinator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM-budget block sizes: three f32 buffers of 128x128 = 3 * 64 KiB,
# comfortably inside a TPU core's ~16 MiB VMEM with double buffering.
BM = 128
BN = 128
BK = 128


def _pick(block: int, dim: int) -> int:
    """Largest block <= `block` that divides `dim` (dims here are powers of 2)."""
    b = min(block, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int, act: str, has_bias: bool, b_ref=None):
    """One (i, j, k) grid step: accumulate x_block @ w_block into o_block.

    The epilogue (bias + activation) runs only on the final K step so the
    accumulator in VMEM holds the raw partial sums until then.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        z = o_ref[...]
        if has_bias:
            z = z + b_ref[...]
        o_ref[...] = ref.act_apply(z, act)


def matmul_fwd_pallas(x, w, b=None, act: str = "none"):
    """y = act(x @ w + b) via the tiled Pallas kernel. x: [M,K], w: [K,N]."""
    m, kdim = x.shape
    _, n = w.shape
    bm, bn, bk = _pick(BM, m), _pick(BN, n), _pick(BK, kdim)
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(b)

    kern = functools.partial(
        _matmul_kernel, nk=nk, act=act, has_bias=b is not None
    )
    if b is not None:
        # reorder: pallas passes refs positionally (x, w, b, o)
        def kern(x_ref, w_ref, b_ref, o_ref):  # noqa: F811
            _matmul_kernel(x_ref, w_ref, o_ref, nk=nk, act=act, has_bias=True, b_ref=b_ref)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(*args)


def _actgrad_kernel(z_ref, gy_ref, o_ref, *, act: str):
    """Row-tiled elementwise VPU kernel: gz = gy * act'(z)."""
    o_ref[...] = gy_ref[...] * ref.act_grad(z_ref[...], act)


def actgrad_pallas(z, gy, act: str):
    m, n = z.shape
    bm = _pick(BM, m)
    return pl.pallas_call(
        functools.partial(_actgrad_kernel, act=act),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), z.dtype),
        interpret=True,
    )(z, gy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul(x, w, b, act: str = "none"):
    """Differentiable fused linear layer: act(x @ w + b).

    x: [M, K] f32; w: [K, N] f32; b: [N] f32 (required — pass zeros to
    disable); act in {'none', 'relu', 'gelu'}.
    """
    return matmul_fwd_pallas(x, w, b, act)


def _matmul_vjp_fwd(x, w, b, act):
    return matmul_fwd_pallas(x, w, b, act), (x, w, b)


def _matmul_vjp_bwd(act, res, gy):
    x, w, b = res
    # Recompute pre-activation z (recompute-style backward; keeps the
    # per-layer artifact interface flat: bwd(params, x, gy)).
    if act == "none":
        gz = gy
    else:
        z = matmul_fwd_pallas(x, w, b, "none")
        gz = actgrad_pallas(z, gy, act)
    gx = matmul_fwd_pallas(gz, w.T, jnp.zeros((w.shape[0],), w.dtype), "none")
    gw = matmul_fwd_pallas(x.T, gz, jnp.zeros((gz.shape[1],), x.dtype), "none")
    gb = jnp.sum(gz, axis=0)
    return gx, gw, gb


matmul.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def linear(x, w, b, act: str = "none"):
    """matmul() generalized to inputs with leading batch dims: [..., K]."""
    lead = x.shape[:-1]
    y = matmul(x.reshape(-1, x.shape[-1]), w, b, act)
    return y.reshape(*lead, w.shape[-1])
