"""Row-tiled LayerNorm Pallas kernels (forward + analytic backward).

LayerNorm is VPU work on TPU: each grid step normalizes a (bm, D) block of
rows held in VMEM. The backward kernel implements the standard analytic
gradient; the per-row parts (gx) are computed in-kernel while the parameter
gradients (dgamma, dbeta) are per-block partial sums reduced outside the
kernel (a [nblocks, D] tensor summed over axis 0) to keep the kernel free of
cross-block communication.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
EPS = 1e-5


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def _ln_fwd_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + EPS)
    o_ref[...] = xhat * g_ref[...] + b_ref[...]


def layernorm_fwd_pallas(x, gamma, beta):
    m, d = x.shape
    bm = _pick(BM, m)
    return pl.pallas_call(
        _ln_fwd_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)


def _ln_bwd_kernel(x_ref, g_ref, gy_ref, gx_ref, dg_ref, db_ref):
    x = x_ref[...]
    gy = gy_ref[...]
    gamma = g_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + EPS)
    xhat = (x - mu) * rstd
    gxhat = gy * gamma
    gx_ref[...] = rstd * (
        gxhat
        - jnp.mean(gxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True)
    )
    dg_ref[...] = jnp.sum(gy * xhat, axis=0)[None, :]
    db_ref[...] = jnp.sum(gy, axis=0)[None, :]


def layernorm_bwd_pallas(x, gamma, gy):
    """Returns (gx, dgamma, dbeta)."""
    m, d = x.shape
    bm = _pick(BM, m)
    nb = m // bm
    gx, dg_part, db_part = pl.pallas_call(
        _ln_bwd_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), x.dtype),
            jax.ShapeDtypeStruct((nb, d), x.dtype),
            jax.ShapeDtypeStruct((nb, d), x.dtype),
        ],
        interpret=True,
    )(x, gamma, gy)
    return gx, jnp.sum(dg_part, axis=0), jnp.sum(db_part, axis=0)


@jax.custom_vjp
def layernorm(x, gamma, beta):
    """Differentiable row-wise LayerNorm over the last axis. x: [M, D]."""
    return layernorm_fwd_pallas(x, gamma, beta)


def _ln_vjp_fwd(x, gamma, beta):
    return layernorm_fwd_pallas(x, gamma, beta), (x, gamma)


def _ln_vjp_bwd(res, gy):
    x, gamma = res
    gx, dgamma, dbeta = layernorm_bwd_pallas(x, gamma, gy)
    return gx, dgamma, dbeta


layernorm.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)


def layernorm_nd(x, gamma, beta):
    """layernorm() over the last axis for inputs with leading batch dims."""
    lead = x.shape[:-1]
    y = layernorm(x.reshape(-1, x.shape[-1]), gamma, beta)
    return y.reshape(*lead, x.shape[-1])
