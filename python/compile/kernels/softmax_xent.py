"""Softmax cross-entropy Pallas kernels (loss head of every model).

Forward: a row-tiled kernel computes, per (bm, C) block of logits held in
VMEM, the per-row NLL (numerically stable logsumexp) and the per-block count
of argmax-correct rows. Classes >= `n_valid` are masked to -1e9 so models can
pad their class dimension up to an MXU-friendly multiple (e.g. 100 classes
padded to 128 — see DESIGN.md).

Backward: a second kernel computes (softmax(logits) - onehot(targets)) *
gloss / M in one pass, masked to the valid classes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BM = 128


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def _xent_fwd_kernel(logits_ref, tgt_ref, nll_ref, correct_ref, *, n_valid: int):
    logits = logits_ref[...]
    tgt = tgt_ref[...]
    bm, c = logits.shape
    mask = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1) < n_valid
    masked = jnp.where(mask, logits, -1e9)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(masked - mx), axis=-1)) + mx[:, 0]
    onehot = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1) == tgt[:, None]
    picked = jnp.sum(jnp.where(onehot, masked, 0.0), axis=-1)
    nll_ref[...] = lse - picked
    pred = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    correct_ref[...] = jnp.sum((pred == tgt).astype(jnp.float32))[None]


def softmax_xent_fwd_pallas(logits, targets, n_valid: int):
    """Returns (nll_rows [M], correct_per_block [nb])."""
    m, c = logits.shape
    bm = _pick(BM, m)
    nb = m // bm
    return pl.pallas_call(
        functools.partial(_xent_fwd_kernel, n_valid=n_valid),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), logits.dtype),
            jax.ShapeDtypeStruct((nb,), logits.dtype),
        ],
        interpret=True,
    )(logits, targets)


def _xent_bwd_kernel(logits_ref, tgt_ref, gl_ref, o_ref, *, n_valid: int, m_total: int):
    logits = logits_ref[...]
    tgt = tgt_ref[...]
    bm, c = logits.shape
    mask = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1) < n_valid
    masked = jnp.where(mask, logits, -1e9)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    p = jnp.exp(masked - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1) == tgt[:, None]).astype(
        logits.dtype
    )
    o_ref[...] = (p - onehot) * (gl_ref[0] / m_total) * mask.astype(logits.dtype)


def softmax_xent_bwd_pallas(logits, targets, gloss, n_valid: int):
    m, c = logits.shape
    bm = _pick(BM, m)
    return pl.pallas_call(
        functools.partial(_xent_bwd_kernel, n_valid=n_valid, m_total=m),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), logits.dtype),
        interpret=True,
    )(logits, targets, gloss.reshape(1))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits, targets, n_valid: int):
    """Mean masked cross-entropy. Returns (mean_nll, correct_count).

    logits: [M, C] f32; targets: [M] i32 with values < n_valid. Only the
    mean NLL is differentiable (the correct count gets a zero cotangent).
    """
    nll, correct = softmax_xent_fwd_pallas(logits, targets, n_valid)
    return jnp.mean(nll), jnp.sum(correct)


def _xent_vjp_fwd(logits, targets, n_valid):
    out = softmax_xent(logits, targets, n_valid)
    return out, (logits, targets)


def _xent_vjp_bwd(n_valid, res, g):
    logits, targets = res
    gloss, _gcorrect = g
    glogits = softmax_xent_bwd_pallas(logits, targets, jnp.asarray(gloss), n_valid)
    gtargets = np.zeros(targets.shape, jax.dtypes.float0)
    return glogits, gtargets


softmax_xent.defvjp(_xent_vjp_fwd, _xent_vjp_bwd)
