"""AOT compile path: lower every layer's fwd/bwd to HLO text + manifest.json.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the `xla` crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Layers whose `share_key` matches share one artifact pair (e.g. all GPT blocks
of one config lower to a single fwd/bwd HLO that Rust compiles once and
executes per layer) — this keeps both AOT time and PJRT compile time linear
in the number of *distinct* layer shapes, not network depth.

Python runs exactly once (`make artifacts`); the Rust binary is self-contained
afterwards and never touches Python on the training path.

Usage:
    cd python && python -m compile.aot --out ../artifacts [--scale smoke]
                                       [--models gpt_mini,mlpnet18]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def kept_inputs(lowered, n_args: int):
    """Indices of the flat inputs jax actually kept after DCE.

    jax.jit prunes unused inputs from the lowered module (e.g. a bias that
    only receives `sum(gy)` in the backward is not *read* by it). The Rust
    runtime must supply exactly the kept buffers, so the manifest records
    this list per artifact.
    """
    kept = lowered._lowering.compile_args.get("kept_var_idx")
    if kept is None:
        return list(range(n_args))
    return sorted(kept)


def lower_layer(layer: M.LayerDef):
    """Returns (fwd_hlo_text, bwd_hlo_text, fwd_kept, bwd_kept)."""
    fwd_specs = M.fwd_arg_specs(layer)
    bwd_specs = M.bwd_arg_specs(layer)
    fwd = jax.jit(M.fwd_flat(layer)).lower(*fwd_specs)
    bwd = jax.jit(M.bwd_flat(layer)).lower(*bwd_specs)
    return (
        to_hlo_text(fwd),
        to_hlo_text(bwd),
        kept_inputs(fwd, len(fwd_specs)),
        kept_inputs(bwd, len(bwd_specs)),
    )


def emit(out_dir: str, scale: str, only_models=None, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    reg = M.registry(scale)
    if only_models:
        reg = {k: v for k, v in reg.items() if k in only_models}

    manifest = {"format": 1, "scale": scale, "models": {}}
    emitted: dict[str, tuple] = {}  # share_key -> (fwd_file, bwd_file, fwd_kept, bwd_kept)

    for mname, mdef in reg.items():
        mlayers = []
        for layer in mdef.layers:
            if layer.share_key not in emitted:
                fwd_txt, bwd_txt, fwd_kept, bwd_kept = lower_layer(layer)
                stem = hashlib.sha1(layer.share_key.encode()).hexdigest()[:10]
                fwd_file = f"{layer.share_key.split('_')[0]}_{stem}.fwd.hlo.txt"
                bwd_file = f"{layer.share_key.split('_')[0]}_{stem}.bwd.hlo.txt"
                with open(os.path.join(out_dir, fwd_file), "w") as f:
                    f.write(fwd_txt)
                with open(os.path.join(out_dir, bwd_file), "w") as f:
                    f.write(bwd_txt)
                emitted[layer.share_key] = (fwd_file, bwd_file, fwd_kept, bwd_kept)
                if verbose:
                    print(f"  lowered {layer.share_key} "
                          f"({len(fwd_txt)//1024} KiB fwd, {len(bwd_txt)//1024} KiB bwd)")
            fwd_file, bwd_file, fwd_kept, bwd_kept = emitted[layer.share_key]
            mlayers.append({
                "name": layer.name,
                "kind": layer.kind,
                "share_key": layer.share_key,
                "fwd": fwd_file,
                "bwd": bwd_file,
                "fwd_kept": fwd_kept,
                "bwd_kept": bwd_kept,
                "params": [
                    {"name": p.name, "shape": list(p.shape),
                     "init": p.init, "scale": p.scale}
                    for p in layer.params
                ],
                "x_shape": list(layer.x_shape),
                "x_dtype": layer.x_dtype,
                "y_shape": list(layer.y_shape) if layer.y_shape else None,
                "targets_shape": (list(layer.targets_shape)
                                  if layer.targets_shape else None),
                "fwd_flops": layer.fwd_flops,
                "bwd_flops": layer.bwd_flops,
            })
        manifest["models"][mname] = {
            "batch": mdef.batch,
            "task": mdef.task,
            "n_valid_classes": mdef.n_valid_classes,
            "metric": mdef.metric,
            "data": mdef.data,
            "param_count": mdef.param_count(),
            "layers": mlayers,
        }
        if verbose:
            print(f"model {mname}: {len(mdef.layers)} layers, "
                  f"{mdef.param_count():,} params")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--scale", default="default", choices=["default", "smoke"])
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of models to emit")
    args = ap.parse_args()
    only = args.models.split(",") if args.models else None
    emit(args.out, args.scale, only)
    print(f"manifest + artifacts written to {args.out}")


if __name__ == "__main__":
    main()
