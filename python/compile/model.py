"""L2 — layered JAX model definitions for the LayUp reproduction.

Every model is expressed as an ordered list of `LayerDef`s. For each layer we
AOT-lower TWO flat-signature functions to HLO text (see aot.py):

    fwd:  (*params, x[, targets])      -> (y,)            kind: first|mid
                                       -> (loss, metric)   kind: loss
    bwd:  (*params, x, gy)             -> (*gparams, gx)   kind: mid
          (*params, x, gy)             -> (*gparams,)      kind: first
          (*params, x, targets)        -> (*gparams, gx)   kind: loss  (cotangent 1 on loss)

This per-layer factoring is the load-bearing design decision of the repo: it
lets the Rust coordinator (L3) run backpropagation layer by layer, publishing
each layer's gradient to the gossip/updater threads the moment it exists —
the mechanism of LayUp Algorithm 1. Backward functions are recompute-style
(they take the same inputs as forward plus the output cotangent), which keeps
artifact interfaces flat and reproduces the paper's ~2x bwd/fwd timing ratio
(Table A4).

All heavy compute inside `fwd` goes through the L1 Pallas kernels
(`kernels.linear`, `kernels.layernorm_nd`, `kernels.attention`,
`kernels.softmax_xent`); their custom VJPs make the lowered backward HLO
Pallas-built as well.

Models defined here (shapes chosen as powers of two for the 128-tile kernels;
see DESIGN.md for the paper-scale → repo-scale substitution table):

  mlpnet18 / mlpnet50  — residual-MLP analogs of ResNet-18/50 (stem + K
                         residual blocks + classifier), 100-way synthetic
                         vision classification (class dim padded to 128).
  gpt_mini             — GPT-2-architecture LM (learned pos-emb, pre-LN
                         blocks, causal attention, untied head).
  rnn_sentiment        — 2-layer tanh-RNN sentiment classifier (Table A3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import kernels as K


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'uniform'
    scale: float = 0.02   # stddev for normal, limit for uniform


@dataclasses.dataclass
class LayerDef:
    name: str
    kind: str                   # 'first' | 'mid' | 'loss'
    share_key: str              # layers with equal keys share HLO artifacts
    params: list                # list[ParamSpec]
    x_shape: tuple
    x_dtype: str                # 'f32' | 'i32'
    y_shape: Optional[tuple]    # None for loss layers
    fwd: Callable               # fwd(params_list, x[, targets])
    has_targets: bool = False
    targets_shape: Optional[tuple] = None
    fwd_flops: int = 0
    bwd_flops: int = 0


@dataclasses.dataclass
class ModelDef:
    name: str
    layers: list
    batch: int
    task: str                   # 'classification' | 'lm'
    n_valid_classes: int        # classes (classification) or vocab (lm)
    data: dict                  # dataset spec consumed by rust data generators
    metric: str                 # 'acc_count' (correct predictions) | 'acc_count_tokens'

    def param_count(self) -> int:
        n = 0
        for l in self.layers:
            for p in l.params:
                sz = 1
                for d in p.shape:
                    sz *= d
                n += sz
        return n


def _mm_flops(m, k, n):
    return 2 * m * k * n


# ---------------------------------------------------------------------------
# Vision: residual-MLP analog of ResNet ("MLPNet")
# ---------------------------------------------------------------------------

def _stem_fwd(params, x):
    w, b = params
    return K.matmul(x, w, b, "relu")


def _resblock_fwd(params, x):
    g, beta, w1, b1, w2, b2 = params
    h = K.layernorm(x, g, beta)
    h = K.matmul(h, w1, b1, "relu")
    h = K.matmul(h, w2, b2, "none")
    return x + h


def _make_cls_fwd(n_valid):
    def _cls_fwd(params, x, targets):
        w, b = params
        logits = K.matmul(x, w, b, "none")
        return K.softmax_xent(logits, targets, n_valid)
    return _cls_fwd


def mlpnet(name: str, n_blocks: int, batch=128, n_in=256, hidden=256,
           n_classes=100, class_pad=128) -> ModelDef:
    """Residual-MLP vision model: stem -> n_blocks residual blocks -> classifier."""
    layers = []
    he = (2.0 / n_in) ** 0.5
    layers.append(LayerDef(
        name="stem", kind="first", share_key=f"mlp_stem_{batch}x{n_in}x{hidden}",
        params=[ParamSpec("w", (n_in, hidden), "normal", he),
                ParamSpec("b", (hidden,), "zeros")],
        x_shape=(batch, n_in), x_dtype="f32", y_shape=(batch, hidden),
        fwd=_stem_fwd,
        fwd_flops=_mm_flops(batch, n_in, hidden),
        bwd_flops=2 * _mm_flops(batch, n_in, hidden),
    ))
    heh = (2.0 / hidden) ** 0.5
    for i in range(n_blocks):
        layers.append(LayerDef(
            name=f"block{i}", kind="mid", share_key=f"mlp_block_{batch}x{hidden}",
            params=[ParamSpec("ln_g", (hidden,), "ones"),
                    ParamSpec("ln_b", (hidden,), "zeros"),
                    ParamSpec("w1", (hidden, hidden), "normal", heh),
                    ParamSpec("b1", (hidden,), "zeros"),
                    ParamSpec("w2", (hidden, hidden), "normal", heh / (2 * n_blocks) ** 0.5),
                    ParamSpec("b2", (hidden,), "zeros")],
            x_shape=(batch, hidden), x_dtype="f32", y_shape=(batch, hidden),
            fwd=_resblock_fwd,
            fwd_flops=2 * _mm_flops(batch, hidden, hidden),
            bwd_flops=4 * _mm_flops(batch, hidden, hidden),
        ))
    layers.append(LayerDef(
        name="classifier", kind="loss", share_key=f"mlp_cls_{batch}x{hidden}x{class_pad}v{n_classes}",
        params=[ParamSpec("w", (hidden, class_pad), "normal", (1.0 / hidden) ** 0.5),
                ParamSpec("b", (class_pad,), "zeros")],
        x_shape=(batch, hidden), x_dtype="f32", y_shape=None,
        fwd=_make_cls_fwd(n_classes),
        has_targets=True, targets_shape=(batch,),
        fwd_flops=_mm_flops(batch, hidden, class_pad),
        bwd_flops=2 * _mm_flops(batch, hidden, class_pad),
    ))
    return ModelDef(
        name=name, layers=layers, batch=batch, task="classification",
        n_valid_classes=n_classes,
        data={"kind": "vision", "n_in": n_in, "n_classes": n_classes},
        metric="acc_count",
    )


# ---------------------------------------------------------------------------
# GPT: pre-LN transformer LM (GPT-2 architecture at repo scale)
# ---------------------------------------------------------------------------

def _make_embed_fwd(seq, dim):
    def _embed_fwd(params, tokens):
        wte, wpe = params
        return wte[tokens] + wpe[None, :, :]
    return _embed_fwd


def _make_block_fwd(n_head):
    def _block_fwd(params, x):
        (ln1_g, ln1_b, wqkv, bqkv, wproj, bproj,
         ln2_g, ln2_b, wfc1, bfc1, wfc2, bfc2) = params
        b, s, d = x.shape
        dh = d // n_head
        a = K.layernorm_nd(x, ln1_g, ln1_b)
        qkv = K.linear(a, wqkv, bqkv, "none")          # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def fold(t):  # [B, S, D] -> [B*H, S, Dh]
            return t.reshape(b, s, n_head, dh).transpose(0, 2, 1, 3).reshape(b * n_head, s, dh)

        def unfold(t):
            return t.reshape(b, n_head, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)

        o = K.attention(fold(q), fold(k), fold(v), True)
        x = x + K.linear(unfold(o), wproj, bproj, "none")
        m = K.layernorm_nd(x, ln2_g, ln2_b)
        h = K.linear(m, wfc1, bfc1, "gelu")
        return x + K.linear(h, wfc2, bfc2, "none")
    return _block_fwd


def _make_lmhead_fwd(vocab):
    def _lmhead_fwd(params, x, targets):
        lnf_g, lnf_b, wout = params
        b, s, d = x.shape
        h = K.layernorm_nd(x, lnf_g, lnf_b)
        logits = K.matmul(h.reshape(b * s, d), wout, jnp.zeros((wout.shape[1],), x.dtype), "none")
        return K.softmax_xent(logits, targets.reshape(b * s), vocab)
    return _lmhead_fwd


def gpt(name: str, batch=8, seq=64, vocab=512, dim=128, n_head=4,
        n_layer=4, ffn=None) -> ModelDef:
    """GPT-2-architecture causal LM at repo scale."""
    ffn = ffn or 4 * dim
    rows = batch * seq
    layers = [LayerDef(
        name="embed", kind="first", share_key=f"gpt_embed_{batch}x{seq}x{vocab}x{dim}",
        params=[ParamSpec("wte", (vocab, dim), "normal", 0.02),
                ParamSpec("wpe", (seq, dim), "normal", 0.01)],
        x_shape=(batch, seq), x_dtype="i32", y_shape=(batch, seq, dim),
        fwd=_make_embed_fwd(seq, dim),
        fwd_flops=2 * rows * dim,
        bwd_flops=4 * rows * dim,
    )]
    attn_flops = _mm_flops(rows, dim, 3 * dim) + 4 * batch * n_head * seq * seq * (dim // n_head) \
        + _mm_flops(rows, dim, dim)
    mlp_flops = 2 * _mm_flops(rows, dim, ffn)
    proj_std = 0.02 / (2 * n_layer) ** 0.5
    for i in range(n_layer):
        layers.append(LayerDef(
            name=f"block{i}", kind="mid",
            share_key=f"gpt_block_{batch}x{seq}x{dim}h{n_head}f{ffn}",
            params=[ParamSpec("ln1_g", (dim,), "ones"), ParamSpec("ln1_b", (dim,), "zeros"),
                    ParamSpec("wqkv", (dim, 3 * dim), "normal", 0.02),
                    ParamSpec("bqkv", (3 * dim,), "zeros"),
                    ParamSpec("wproj", (dim, dim), "normal", proj_std),
                    ParamSpec("bproj", (dim,), "zeros"),
                    ParamSpec("ln2_g", (dim,), "ones"), ParamSpec("ln2_b", (dim,), "zeros"),
                    ParamSpec("wfc1", (dim, ffn), "normal", 0.02),
                    ParamSpec("bfc1", (ffn,), "zeros"),
                    ParamSpec("wfc2", (ffn, dim), "normal", proj_std),
                    ParamSpec("bfc2", (dim,), "zeros")],
            x_shape=(batch, seq, dim), x_dtype="f32", y_shape=(batch, seq, dim),
            fwd=_make_block_fwd(n_head),
            fwd_flops=attn_flops + mlp_flops,
            bwd_flops=2 * (attn_flops + mlp_flops),
        ))
    layers.append(LayerDef(
        name="lm_head", kind="loss", share_key=f"gpt_head_{batch}x{seq}x{dim}x{vocab}",
        params=[ParamSpec("lnf_g", (dim,), "ones"), ParamSpec("lnf_b", (dim,), "zeros"),
                ParamSpec("wout", (dim, vocab), "normal", 0.02)],
        x_shape=(batch, seq, dim), x_dtype="f32", y_shape=None,
        fwd=_make_lmhead_fwd(vocab),
        has_targets=True, targets_shape=(batch, seq),
        fwd_flops=_mm_flops(rows, dim, vocab),
        bwd_flops=2 * _mm_flops(rows, dim, vocab),
    ))
    return ModelDef(
        name=name, layers=layers, batch=batch, task="lm", n_valid_classes=vocab,
        data={"kind": "lm", "vocab": vocab, "seq": seq},
        metric="acc_count_tokens",
    )


# ---------------------------------------------------------------------------
# RNN sentiment classifier (Table A3 analog)
# ---------------------------------------------------------------------------

def _make_rnn1_fwd(hidden):
    def _rnn1_fwd(params, tokens):
        emb, wx, wh, bh = params
        b, s = tokens.shape
        xseq = emb[tokens]                             # [B, S, E]
        h0 = jnp.zeros((b, hidden), xseq.dtype)

        def step(h, x_t):
            h = jnp.tanh(K.matmul(x_t, wx, bh, "none") + h @ wh)
            return h, h

        _, hs = jax.lax.scan(step, h0, xseq.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2)                   # [B, S, H]
    return _rnn1_fwd


def _make_rnn2_fwd():
    def _rnn2_fwd(params, xseq):
        wx, wh, bh = params
        b, s, hdim = xseq.shape
        h0 = jnp.zeros((b, hdim), xseq.dtype)

        def step(h, x_t):
            h = jnp.tanh(K.matmul(x_t, wx, bh, "none") + h @ wh)
            return h, h

        _, hs = jax.lax.scan(step, h0, xseq.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2)
    return _rnn2_fwd


def _make_sentiment_cls_fwd(n_classes):
    def _fwd(params, xseq, targets):
        w, b = params
        pooled = jnp.mean(xseq, axis=1)                # [B, H]
        logits = K.matmul(pooled, w, b, "none")
        return K.softmax_xent(logits, targets, n_classes)
    return _fwd


def rnn_sentiment(name="rnn_sentiment", batch=64, seq=32, vocab=256,
                  emb=64, hidden=128, n_classes=2, class_pad=128) -> ModelDef:
    """2-layer tanh-RNN mean-pool sentiment classifier (IMDb/LSTM analog)."""
    rows = batch * seq
    layers = [
        LayerDef(
            name="rnn1", kind="first", share_key=f"rnn1_{batch}x{seq}x{vocab}x{emb}x{hidden}",
            params=[ParamSpec("emb", (vocab, emb), "normal", 0.1),
                    ParamSpec("wx", (emb, hidden), "normal", (1.0 / emb) ** 0.5),
                    ParamSpec("wh", (hidden, hidden), "normal", (0.5 / hidden) ** 0.5),
                    ParamSpec("bh", (hidden,), "zeros")],
            x_shape=(batch, seq), x_dtype="i32", y_shape=(batch, seq, hidden),
            fwd=_make_rnn1_fwd(hidden),
            fwd_flops=rows * 2 * (emb + hidden) * hidden,
            bwd_flops=2 * rows * 2 * (emb + hidden) * hidden,
        ),
        LayerDef(
            name="rnn2", kind="mid", share_key=f"rnn2_{batch}x{seq}x{hidden}",
            params=[ParamSpec("wx", (hidden, hidden), "normal", (1.0 / hidden) ** 0.5),
                    ParamSpec("wh", (hidden, hidden), "normal", (0.5 / hidden) ** 0.5),
                    ParamSpec("bh", (hidden,), "zeros")],
            x_shape=(batch, seq, hidden), x_dtype="f32", y_shape=(batch, seq, hidden),
            fwd=_make_rnn2_fwd(),
            fwd_flops=rows * 4 * hidden * hidden,
            bwd_flops=2 * rows * 4 * hidden * hidden,
        ),
        LayerDef(
            name="classifier", kind="loss", share_key=f"rnn_cls_{batch}x{seq}x{hidden}v{n_classes}",
            params=[ParamSpec("w", (hidden, class_pad), "normal", (1.0 / hidden) ** 0.5),
                    ParamSpec("b", (class_pad,), "zeros")],
            x_shape=(batch, seq, hidden), x_dtype="f32", y_shape=None,
            fwd=_make_sentiment_cls_fwd(n_classes),
            has_targets=True, targets_shape=(batch,),
            fwd_flops=2 * batch * hidden * class_pad,
            bwd_flops=4 * batch * hidden * class_pad,
        ),
    ]
    return ModelDef(
        name=name, layers=layers, batch=batch, task="classification",
        n_valid_classes=n_classes,
        data={"kind": "sentiment", "vocab": vocab, "seq": seq, "n_classes": n_classes},
        metric="acc_count",
    )


# ---------------------------------------------------------------------------
# Registry + flat-signature artifact functions
# ---------------------------------------------------------------------------

def registry(scale: str = "default") -> dict:
    """All models emitted by `make artifacts`.

    `scale='smoke'` shrinks everything for fast CI-style runs.
    """
    if scale == "smoke":
        return {
            "mlpnet18": mlpnet("mlpnet18", 2, batch=32, n_in=64, hidden=64,
                               n_classes=10, class_pad=16),
            "gpt_mini": gpt("gpt_mini", batch=2, seq=16, vocab=64, dim=32,
                            n_head=2, n_layer=2),
            "rnn_sentiment": rnn_sentiment(batch=8, seq=8, vocab=32, emb=8,
                                           hidden=16, class_pad=16),
        }
    # Default scale is sized for the single-CPU PJRT substrate this repo
    # trains on (DESIGN.md substitution table): depth structure matches the
    # paper's models (8 vs 16 residual blocks ~ ResNet-18/50; pre-LN GPT),
    # widths are cut so a full multi-algorithm table regenerates in minutes.
    return {
        "mlpnet18": mlpnet("mlpnet18", 8, batch=64, n_in=128, hidden=128),
        "mlpnet50": mlpnet("mlpnet50", 16, batch=64, n_in=128, hidden=128),
        "gpt_mini": gpt("gpt_mini", batch=4, seq=64, vocab=256, dim=128,
                        n_head=4, n_layer=3, ffn=256),
        "rnn_sentiment": rnn_sentiment(batch=32, seq=16, vocab=128, emb=32,
                                       hidden=64),
    }


def _dtype(s: str):
    return {"f32": jnp.float32, "i32": jnp.int32}[s]


def fwd_flat(layer: LayerDef) -> Callable:
    """Flat-signature forward: (*params, x[, targets]) -> tuple of outputs."""
    n_p = len(layer.params)

    def f(*args):
        params = list(args[:n_p])
        out = layer.fwd(params, *args[n_p:])
        return out if isinstance(out, tuple) else (out,)

    return f


def bwd_flat(layer: LayerDef) -> Callable:
    """Flat-signature recompute-style backward (see module docstring)."""
    n_p = len(layer.params)

    if layer.kind == "loss":
        def f(*args):
            params = list(args[:n_p])
            x, targets = args[n_p], args[n_p + 1]

            def scalar_loss(params, x):
                loss, _metric = layer.fwd(params, x, targets)
                return loss

            gp, gx = jax.grad(scalar_loss, argnums=(0, 1))(params, x)
            return (*gp, gx)
        return f

    if layer.kind == "first":
        def f(*args):
            params = list(args[:n_p])
            x, gy = args[n_p], args[n_p + 1]
            _, vjp = jax.vjp(lambda p: layer.fwd(p, x), params)
            (gp,) = vjp(gy)
            return tuple(gp)
        return f

    def f(*args):
        params = list(args[:n_p])
        x, gy = args[n_p], args[n_p + 1]
        _, vjp = jax.vjp(lambda p, x: layer.fwd(p, x), params, x)
        gp, gx = vjp(gy)
        return (*gp, gx)
    return f


def fwd_arg_specs(layer: LayerDef):
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in layer.params]
    specs.append(jax.ShapeDtypeStruct(layer.x_shape, _dtype(layer.x_dtype)))
    if layer.kind == "loss":
        specs.append(jax.ShapeDtypeStruct(layer.targets_shape, jnp.int32))
    return specs


def bwd_arg_specs(layer: LayerDef):
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in layer.params]
    specs.append(jax.ShapeDtypeStruct(layer.x_shape, _dtype(layer.x_dtype)))
    if layer.kind == "loss":
        specs.append(jax.ShapeDtypeStruct(layer.targets_shape, jnp.int32))
    else:
        specs.append(jax.ShapeDtypeStruct(layer.y_shape, jnp.float32))
    return specs
