"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/seeds; explicit cases pin the shapes the AOT
models actually use. These tests are the core correctness signal for the
artifacts the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

# shapes are powers of two (kernel block-picking contract)
POW2 = st.sampled_from([8, 16, 32, 64, 128, 256])
POW2_SMALL = st.sampled_from([8, 16, 32, 64])
ACTS = st.sampled_from(["none", "relu", "gelu"])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def rnd(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype("float32"))


# ---------------------------------------------------------------------------
# matmul + bias + activation
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=POW2, k=POW2, n=POW2, act=ACTS, seed=SEEDS)
def test_matmul_fwd_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, m, k), rnd(rng, k, n), rnd(rng, n)
    got = K.matmul(x, w, b, act)
    want = ref.matmul(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(m=POW2_SMALL, k=POW2_SMALL, n=POW2_SMALL, act=ACTS, seed=SEEDS)
def test_matmul_grads_match_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, m, k), rnd(rng, k, n), rnd(rng, n)

    def loss_k(x, w, b):
        return jnp.sum(K.matmul(x, w, b, act) ** 2)

    def loss_r(x, w, b):
        return jnp.sum(ref.matmul(x, w, b, act) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)


def test_matmul_large_tiled_shape():
    """M, K, N > 128 exercises the multi-block accumulation path."""
    rng = np.random.default_rng(7)
    x, w, b = rnd(rng, 256, 256), rnd(rng, 256, 256), rnd(rng, 256)
    np.testing.assert_allclose(
        K.matmul(x, w, b, "gelu"), ref.matmul(x, w, b, "gelu"), rtol=1e-3, atol=1e-3
    )


def test_linear_batched_3d():
    rng = np.random.default_rng(8)
    x = rnd(rng, 4, 16, 32)
    w, b = rnd(rng, 32, 64), rnd(rng, 64)
    got = K.linear(x, w, b, "relu")
    want = ref.matmul(x.reshape(-1, 32), w, b, "relu").reshape(4, 16, 64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=POW2, d=POW2, seed=SEEDS)
def test_layernorm_fwd_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = rnd(rng, m, d), rnd(rng, d), rnd(rng, d)
    np.testing.assert_allclose(
        K.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=POW2_SMALL, d=POW2_SMALL, seed=SEEDS)
def test_layernorm_bwd_matches_analytic(m, d, seed):
    rng = np.random.default_rng(seed)
    x, g = rnd(rng, m, d), rnd(rng, d)
    gy = rnd(rng, m, d)
    gx, dg, db = K.layernorm_bwd_pallas(x, g, gy)
    rgx, rdg, rdb = ref.layernorm_bwd(x, g, gy)
    np.testing.assert_allclose(gx, rgx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dg, rdg, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(db, rdb, rtol=1e-3, atol=1e-3)


def test_layernorm_bwd_multiblock_param_reduction():
    """M > 128 forces the cross-block dgamma/dbeta partial-sum reduction."""
    rng = np.random.default_rng(9)
    x, g, gy = rnd(rng, 512, 64), rnd(rng, 64), rnd(rng, 512, 64)
    gx, dg, db = K.layernorm_bwd_pallas(x, g, gy)
    rgx, rdg, rdb = ref.layernorm_bwd(x, g, gy)
    np.testing.assert_allclose(dg, rdg, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(db, rdb, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gx, rgx, rtol=1e-3, atol=1e-3)


def test_layernorm_grad_through_custom_vjp():
    rng = np.random.default_rng(10)
    x, g, b = rnd(rng, 64, 32), rnd(rng, 32), rnd(rng, 32)
    gk = jax.grad(lambda x, g, b: jnp.sum(jnp.sin(K.layernorm(x, g, b))),
                  argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(lambda x, g, b: jnp.sum(jnp.sin(ref.layernorm(x, g, b))),
                  argnums=(0, 1, 2))(x, g, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=POW2, c=st.sampled_from([16, 64, 128]), seed=SEEDS,
       frac_valid=st.sampled_from([1.0, 0.8, 0.5]))
def test_xent_fwd_matches_ref(m, c, seed, frac_valid):
    n_valid = max(2, int(c * frac_valid))
    rng = np.random.default_rng(seed)
    logits = rnd(rng, m, c)
    tg = jnp.asarray(rng.integers(0, n_valid, size=(m,)).astype("int32"))
    l, corr = K.softmax_xent(logits, tg, n_valid)
    lr, corr_r = ref.softmax_xent(logits, tg, n_valid)
    np.testing.assert_allclose(l, lr, rtol=1e-5, atol=1e-5)
    assert float(corr) == float(corr_r)


@settings(max_examples=10, deadline=None)
@given(m=POW2_SMALL, seed=SEEDS)
def test_xent_bwd_matches_ref(m, seed):
    c, n_valid = 64, 50
    rng = np.random.default_rng(seed)
    logits = rnd(rng, m, c)
    tg = jnp.asarray(rng.integers(0, n_valid, size=(m,)).astype("int32"))
    gk = jax.grad(lambda lg: K.softmax_xent(lg, tg, n_valid)[0])(logits)
    gr = ref.softmax_xent_bwd(logits, tg, n_valid)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-6)


def test_xent_padded_classes_get_zero_grad():
    rng = np.random.default_rng(11)
    logits = rnd(rng, 32, 128)
    tg = jnp.asarray(rng.integers(0, 100, size=(32,)).astype("int32"))
    g = jax.grad(lambda lg: K.softmax_xent(lg, tg, 100)[0])(logits)
    assert float(jnp.max(jnp.abs(g[:, 100:]))) == 0.0


def test_xent_loss_scales_with_cotangent():
    """The bwd kernel must honor a non-unit loss cotangent."""
    rng = np.random.default_rng(12)
    logits = rnd(rng, 16, 16)
    tg = jnp.asarray(rng.integers(0, 16, size=(16,)).astype("int32"))
    g1 = jax.grad(lambda lg: 1.0 * K.softmax_xent(lg, tg, 16)[0])(logits)
    g3 = jax.grad(lambda lg: 3.0 * K.softmax_xent(lg, tg, 16)[0])(logits)
    np.testing.assert_allclose(3.0 * g1, g3, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(h=st.sampled_from([1, 2, 4, 8]), s=st.sampled_from([8, 16, 64]),
       dh=st.sampled_from([8, 16, 32]), causal=st.booleans(), seed=SEEDS)
def test_attention_fwd_matches_ref(h, s, dh, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rnd(rng, h, s, dh), rnd(rng, h, s, dh), rnd(rng, h, s, dh)
    np.testing.assert_allclose(
        K.attention(q, k, v, causal), ref.attention(q, k, v, causal),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=8, deadline=None)
@given(seed=SEEDS, causal=st.booleans())
def test_attention_bwd_matches_ref(seed, causal):
    rng = np.random.default_rng(seed)
    q, k, v = (rnd(rng, 4, 16, 8) for _ in range(3))

    def loss_k(q, k, v):
        return jnp.sum(K.attention(q, k, v, causal) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


def test_attention_causal_masks_future():
    """Output at position t must not depend on tokens > t."""
    rng = np.random.default_rng(13)
    q, k, v = (rnd(rng, 1, 16, 8) for _ in range(3))
    o1 = K.attention(q, k, v, True)
    v2 = v.at[0, 10:, :].set(999.0)
    k2 = k.at[0, 10:, :].set(-7.0)
    o2 = K.attention(q, k2, v2, True)
    np.testing.assert_allclose(o1[0, :10], o2[0, :10], rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(o1[0, 10:] - o2[0, 10:]))) > 1e-3
