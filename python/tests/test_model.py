"""L2 correctness: the per-layer fwd/bwd factoring must equal end-to-end jax.

For each model in the smoke registry we:
  1. run the layer chain forward and compare the loss with a single composed
     jax forward;
  2. run the layer chain *backward* exactly the way the Rust coordinator does
     (loss layer bwd, then mid/first layers in reverse, threading gx) and
     compare every parameter gradient with `jax.grad` of the composed loss;
  3. sanity-check the manifest metadata (shapes, dedup, flops).

This validates the contract the HLO artifacts implement before Rust ever
sees them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import aot


def init_params(layer, rng):
    out = []
    for p in layer.params:
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, jnp.float32))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(0, p.scale, size=p.shape).astype("float32")))
    return out


def make_batch(mdef, rng):
    first = mdef.layers[0]
    if first.x_dtype == "i32":
        vocab = mdef.data.get("vocab", 32)
        x = jnp.asarray(rng.integers(0, vocab, size=first.x_shape).astype("int32"))
    else:
        x = jnp.asarray(rng.normal(size=first.x_shape).astype("float32"))
    loss_layer = mdef.layers[-1]
    tgt = jnp.asarray(
        rng.integers(0, mdef.n_valid_classes, size=loss_layer.targets_shape).astype("int32")
    )
    return x, tgt


def composed_loss(mdef, all_params, x, tgt):
    h = x
    for layer, p in zip(mdef.layers[:-1], all_params[:-1]):
        h = layer.fwd(list(p), h)
    loss, metric = mdef.layers[-1].fwd(list(all_params[-1]), h, tgt)
    return loss, metric


def layerwise_backward(mdef, all_params, x, tgt):
    """Mimic the Rust coordinator: fwd chain saving inputs, then bwd chain."""
    inputs = [x]
    h = x
    for layer, p in zip(mdef.layers[:-1], all_params[:-1]):
        h = M.fwd_flat(layer)(*p, inputs[-1])[0]
        inputs.append(h)

    grads = [None] * len(mdef.layers)
    loss_layer = mdef.layers[-1]
    out = M.bwd_flat(loss_layer)(*all_params[-1], inputs[-1], tgt)
    grads[-1] = out[: len(loss_layer.params)]
    gx = out[-1]
    for i in range(len(mdef.layers) - 2, -1, -1):
        layer = mdef.layers[i]
        out = M.bwd_flat(layer)(*all_params[i], inputs[i], gx)
        grads[i] = out[: len(layer.params)]
        if layer.kind != "first":
            gx = out[-1]
    return grads


@pytest.fixture(scope="module")
def smoke_registry():
    return M.registry("smoke")


@pytest.mark.parametrize("mname", ["mlpnet18", "gpt_mini", "rnn_sentiment"])
def test_layer_chain_forward_equals_composed(smoke_registry, mname):
    mdef = smoke_registry[mname]
    rng = np.random.default_rng(42)
    params = [init_params(l, rng) for l in mdef.layers]
    x, tgt = make_batch(mdef, rng)

    h = x
    for layer, p in zip(mdef.layers[:-1], params[:-1]):
        h = M.fwd_flat(layer)(*p, h)[0]
    loss_chain, metric_chain = M.fwd_flat(mdef.layers[-1])(*params[-1], h, tgt)
    loss_comp, metric_comp = composed_loss(mdef, params, x, tgt)
    np.testing.assert_allclose(loss_chain, loss_comp, rtol=1e-5, atol=1e-6)
    assert float(metric_chain) == float(metric_comp)


@pytest.mark.parametrize("mname", ["mlpnet18", "gpt_mini", "rnn_sentiment"])
def test_layerwise_backward_equals_jax_grad(smoke_registry, mname):
    mdef = smoke_registry[mname]
    rng = np.random.default_rng(7)
    params = [init_params(l, rng) for l in mdef.layers]
    x, tgt = make_batch(mdef, rng)

    chain_grads = layerwise_backward(mdef, params, x, tgt)
    auto_grads = jax.grad(lambda ps: composed_loss(mdef, ps, x, tgt)[0])(params)

    for li, (layer, cg, ag) in enumerate(zip(mdef.layers, chain_grads, auto_grads)):
        for pi, (a, b) in enumerate(zip(cg, ag)):
            np.testing.assert_allclose(
                a, b, rtol=2e-3, atol=2e-4,
                err_msg=f"{mname} layer {li} ({layer.name}) param {pi}",
            )


@pytest.mark.parametrize("mname", ["mlpnet18", "gpt_mini", "rnn_sentiment"])
def test_loss_decreases_under_sgd(smoke_registry, mname):
    """Five layer-wise SGD steps on a fixed batch must reduce the loss."""
    mdef = smoke_registry[mname]
    rng = np.random.default_rng(3)
    params = [init_params(l, rng) for l in mdef.layers]
    x, tgt = make_batch(mdef, rng)
    lr = 0.1

    loss0 = float(composed_loss(mdef, params, x, tgt)[0])
    for _ in range(5):
        grads = layerwise_backward(mdef, params, x, tgt)
        params = [
            [p - lr * g for p, g in zip(lp, lg)] for lp, lg in zip(params, grads)
        ]
    loss1 = float(composed_loss(mdef, params, x, tgt)[0])
    assert loss1 < loss0, f"{mname}: {loss0} -> {loss1}"


def test_manifest_smoke(tmp_path):
    man = aot.emit(str(tmp_path), "smoke", verbose=False)
    assert set(man["models"]) == {"mlpnet18", "gpt_mini", "rnn_sentiment"}
    for mname, m in man["models"].items():
        layers = m["layers"]
        assert layers[0]["kind"] == "first"
        assert layers[-1]["kind"] == "loss"
        assert all(l["kind"] == "mid" for l in layers[1:-1])
        # every referenced artifact exists on disk
        for l in layers:
            assert (tmp_path / l["fwd"]).exists()
            assert (tmp_path / l["bwd"]).exists()
            assert l["fwd_flops"] > 0 and l["bwd_flops"] >= l["fwd_flops"]
        # activation shapes chain
        for a, b in zip(layers[:-1], layers[1:]):
            assert a["y_shape"] == b["x_shape"], (mname, a["name"], b["name"])


def test_manifest_dedup_shares_block_artifacts(tmp_path):
    man = aot.emit(str(tmp_path), "smoke", only_models=["mlpnet18"], verbose=False)
    blocks = [l for l in man["models"]["mlpnet18"]["layers"] if l["kind"] == "mid"]
    assert len(blocks) >= 2
    assert len({b["fwd"] for b in blocks}) == 1, "mid blocks must share one artifact"


def test_param_count_default_registry():
    reg = M.registry("default")
    # sanity: model sizes in the expected ranges (see DESIGN.md)
    assert 1_000_000 < reg["gpt_mini"].param_count() < 20_000_000 or \
        reg["gpt_mini"].param_count() > 100_000  # repo scale
    assert reg["mlpnet50"].param_count() > reg["mlpnet18"].param_count()
